"""Decision explainability: bounded per-task verdict rings.

PR 8's event ring answers *what happened*; this module answers *why*.
Every drain/admission attempt records a structured ``Verdict`` at the
existing decision sites in ``scheduler/base.py`` / ``gang.py`` /
``preempt.py`` / ``sharded.py``: why each probed device refused
(``memory_short_bytes``, ``slots_full``, ``max_residents``,
``link_headroom``, ``grow_budget``, ``device_dead``), when a waiter was
skipped without probing (``class_memo_skip``, hint skips), which
preemption victim plans were considered and at what cost, who evicted a
task, and where it finally landed. ``JobHandle.explain()`` /
``Cluster.explain(handle)`` read the rings back in one call on both
backends.

Design constraints mirror the tracer's (see ``obs/events.py``):

  1. **Disabled must be free.** Emission sites guard with
     ``ex = self._explain`` / ``if ex is not None`` — one attribute load
     on the hot path when explanation is off.
  2. **Enabled must stay inside the PR-8 budget.** The expensive part of
     a rejection verdict is the per-device reason walk (O(devices) dict
     builds). Two mitigations keep the paired bench gate at <=5%:

     * ``reject()`` takes the reasons **lazily** (a zero-arg callable)
       and COLLAPSES consecutive rejections of the same task: if the
       task's newest verdict is already a rejection, the repeat just
       bumps ``repeats`` and refreshes the timestamp — the device walk
       runs once per parked *episode*, not once per failed probe.
     * ``skip()`` treats probe-avoidance skips (class-memo / hint
       skips, which fire once per drain pass per parked class on deep
       queues) as extensions of the open parked episode: when the
       newest verdict is already a rejection or skip, the bump is two
       in-place attribute writes — no verdict construction at all.
       ``record(..., collapse=True)`` gives the same in-place bump to
       same-action/device repeats at other sites.

  3. **Bounded memory.** Each task keeps a ``deque(maxlen=per_task)``
     verdict ring (last-K wins); the task map itself is bounded at
     ``max_tasks`` by evicting the oldest-inserted task's ring (dict
     insertion order), so a serving fleet that churns millions of uids
     never grows without bound.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, NamedTuple, Optional, Tuple

# raw ring-entry layout (list indices; Verdict materializes on read)
_T = 1          # [0]=seq  [1]=t  [2]=uid  [3]=name
_ACTION = 4     # [4]=action  [5]=device  [6]=reasons
_DEVICE = 5     # [7]=data  [8]=repeats
_DATA = 7
_REPEATS = 8

# -- verdict actions --------------------------------------------------------
# String constants (like the event kinds) so dumps read directly.
ADMITTED = "admitted"            # placed on a device / group
REJECTED = "rejected"            # probed and refused; reasons name devices
SKIPPED = "skipped"              # not probed (class memo / freed-cap hint)
EVICTED = "evicted"              # preempted or device-death victim
SHED = "shed"                    # parked past deadline, failed at a drain
CRASHED = "crashed"              # infeasible / OOM — terminal failure
GROWN = "grown"                  # decode-slot delta admitted
PREEMPT_PLANNED = "preempt_planned"    # arrival won via eviction plan
PREEMPT_REJECTED = "preempt_rejected"  # no affordable victim plan
STOLEN = "stolen"                # sharded: moved toward an idle pod
STEAL_REFUSED = "steal_refused"  # sharded: target pod refused, restored
REHOMED = "rehomed"              # sharded: pod died, re-routed elsewhere

# rejection-reason vocabulary (the ``reason`` key of each reasons entry)
R_DEVICE_DEAD = "device_dead"
R_MEMORY_SHORT = "memory_short_bytes"
R_SLOTS_FULL = "slots_full"
R_MAX_RESIDENTS = "max_residents"
R_LINK_HEADROOM = "link_headroom"
R_GROW_BUDGET = "grow_budget"
R_HOST_GONE = "host_gone"
R_NO_FEASIBLE_GROUP = "no_feasible_group"
R_CLASS_MEMO = "class_memo_skip"
R_HINT_SKIP = "hint_skip"
R_NO_VICTIM_PLAN = "no_victim_plan"


class Verdict:
    """One structured decision record.

    ``seq``     — monotonic per-explainer sequence (decision order).
    ``t``       — backend-timeline seconds (same clock as the tracer).
    ``uid``     — task uid the verdict is about.
    ``name``    — task name (parity across backends; uids differ per leg).
    ``action``  — one of the module constants above.
    ``device``  — GLOBAL flat device index when placement-scoped, else -1.
    ``reasons`` — tuple of dicts, each with a ``reason`` key from the
                  vocabulary plus site-specific detail (``device``,
                  ``short_bytes``, ``short_slots``, ``by``, ``cost_s``…).
    ``data``    — optional dict of extras (victim plans, shard ids, …).
    ``repeats`` — how many consecutive identical outcomes this record
                  collapses (a waiter re-probed 400 times while parked
                  keeps ONE rejection verdict with ``repeats=400``).

    ``Verdict`` is the READ-side materialization: the rings store raw
    9-slot lists (same field order) and ``verdicts()``/``last()`` wrap
    them on access. On deep queues the hot explainer path is the episode
    BUMP (repeat probe of an already-parked task, skip of an
    already-explained class) and, next, the admission append — a list
    literal plus an indexed increment is ~3x cheaper than any class
    construction, which is the difference between fitting the paired
    bench's 5% budget and blowing it.
    """

    __slots__ = ("seq", "t", "uid", "name", "action", "device", "reasons",
                 "data", "repeats")

    def __init__(self, seq: int, t: float, uid: int, name: str, action: str,
                 device: int = -1, reasons: Tuple[dict, ...] = (),
                 data: Optional[dict] = None, repeats: int = 1):
        self.seq = seq
        self.t = t
        self.uid = uid
        self.name = name
        self.action = action
        self.device = device
        self.reasons = reasons
        self.data = data
        self.repeats = repeats

    def __repr__(self) -> str:
        return (f"Verdict(seq={self.seq}, t={self.t:.6f}, uid={self.uid}, "
                f"name={self.name!r}, action={self.action!r}, "
                f"device={self.device}, reasons={self.reasons!r}, "
                f"data={self.data!r}, repeats={self.repeats})")

    def __eq__(self, other: Any) -> bool:
        if not isinstance(other, Verdict):
            return NotImplemented
        return all(getattr(self, f) == getattr(other, f)
                   for f in self.__slots__)

    def as_dict(self) -> Dict[str, Any]:
        return {f: getattr(self, f) for f in self.__slots__}


class Explainer:
    """Bounded per-task last-K verdict rings.

    Thread-safety matches the tracer: dict/deque mutations are single
    C-level ops under the GIL; racing recorders may interleave seqs out
    of order and ``verdicts()`` returns ring order (per-task inserts are
    single-threaded in practice — each task's decisions happen under its
    scheduler's lock).
    """

    def __init__(self, per_task: int = 16, max_tasks: int = 4096, *,
                 clock: Optional[Callable[[], float]] = None):
        if per_task < 1 or max_tasks < 1:
            raise ValueError("per_task and max_tasks must be >= 1")
        self.per_task = per_task
        self.max_tasks = max_tasks
        self._clock: Callable[[], float] = clock or time.monotonic
        self._clock_host: Optional[Any] = None
        # raw 9-slot lists (see layout above); Verdict wraps on read
        self._rings: Dict[int, Deque[list]] = {}
        self._names: Dict[int, str] = {}
        self._count = itertools.count()
        self.recorded = 0                # total verdicts (incl. collapsed)
        self.evicted_tasks = 0           # rings dropped to the task bound

    # -- clock (same late-binding contract as Tracer) ------------------------
    def _now(self) -> float:
        host = self._clock_host
        return host._clock() if host is not None else self._clock()

    def use_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock
        self._clock_host = None

    def use_clock_host(self, host: Any) -> None:
        """Timestamp from ``host._clock()`` read through ``host`` per call,
        so the simulator's virtual-clock swap is followed automatically."""
        self._clock_host = host

    # -- recording -----------------------------------------------------------
    def _ring(self, uid: int, name: str) -> Deque[list]:
        ring = self._rings.get(uid)
        if ring is None:
            if len(self._rings) >= self.max_tasks:
                old = next(iter(self._rings))    # oldest-inserted uid
                del self._rings[old]
                self._names.pop(old, None)
                self.evicted_tasks += 1
            ring = self._rings[uid] = deque(maxlen=self.per_task)
            self._names[uid] = name
        return ring

    def record(self, uid: int, name: str, action: str, *, device: int = -1,
               reasons: Tuple[dict, ...] = (), data: Optional[dict] = None,
               collapse: bool = False) -> None:
        """Append one verdict. With ``collapse=True``, a newest verdict
        with the same action and device is bumped in place (``repeats`` +
        fresh timestamp) instead of appended — keeps drain-pass skip
        noise O(1) per episode in both time and ring space."""
        self.recorded += 1
        ring = self._rings.get(uid)
        if ring is None:
            ring = self._ring(uid, name)
        # clock read inlined (record is on the admit hot path; a method
        # call per verdict is measurable at bench depth)
        host = self._clock_host
        now = host._clock() if host is not None else self._clock()
        if collapse and ring:
            last = ring[-1]
            if last[_ACTION] == action and last[_DEVICE] == device:
                last[_T] = now
                last[_REPEATS] += 1
                return
        ring.append([next(self._count), now, uid, name,
                     action, device, reasons, data, 1])

    def reject(self, uid: int, name: str,
               reasons_fn: Callable[[], Tuple[dict, ...]], *,
               device: int = -1, data: Optional[dict] = None) -> None:
        """Record a probe rejection with LAZY reasons: if the task's
        newest verdict is already a rejection, only ``repeats``/``t`` are
        bumped and ``reasons_fn`` is never called — the O(devices) reason
        walk runs once per parked episode, not once per failed probe."""
        self.recorded += 1
        ring = self._rings.get(uid)
        if ring:
            last = ring[-1]
            if last[_ACTION] == REJECTED:
                last[_T] = self._now()
                last[_REPEATS] += 1
                return
        elif ring is None:
            ring = self._ring(uid, name)
        ring.append([next(self._count), self._now(), uid, name,
                     REJECTED, device, tuple(reasons_fn()), data, 1])

    def skip(self, uid: int, name: str,
             reasons: Tuple[dict, ...] = ()) -> None:
        """Record a probe-avoidance skip (freed-capacity hint, class memo,
        preemption memo). A skip EXTENDS the open parked episode: when the
        task's newest verdict is a rejection or a prior skip, only its
        ``repeats`` counter is bumped — the structured reasons of the
        original rejection still explain why the task is parked, the
        verdict's ``t`` stays the episode's last materialized decision
        time (current state is the live ``explain_queue`` probe's job),
        and the bump is one in-place increment: this fires once per drain
        pass per parked class on deep queues, so it is the single
        hottest explainer path. Only a fresh episode (no ring, or last
        verdict was an admission/eviction) appends a SKIPPED verdict
        carrying the skip reasons."""
        self.recorded += 1
        ring = self._rings.get(uid)
        if ring:
            last = ring[-1]
            act = last[_ACTION]
            if act == SKIPPED or act == REJECTED:
                last[_REPEATS] += 1
                return
        elif ring is None:
            ring = self._ring(uid, name)
        ring.append([next(self._count), self._now(), uid, name,
                     SKIPPED, -1, reasons, None, 1])

    def annotate_last(self, uid: int, key: str, value: Any) -> None:
        """Attach ``key: value`` to the task's newest verdict's data dict
        (in place when the dict exists — O(1) on the repeat path)."""
        ring = self._rings.get(uid)
        if not ring:
            return
        v = ring[-1]
        if v[_DATA] is not None:
            v[_DATA][key] = value
        else:
            v[_DATA] = {key: value}

    # -- reading -------------------------------------------------------------
    def verdicts(self, uid: int) -> List[Verdict]:
        """The task's surviving verdict window, oldest first
        (materialized — mutating the returned Verdicts does not touch
        the ring)."""
        ring = self._rings.get(uid)
        return [Verdict(*r) for r in ring] if ring else []

    def last(self, uid: int) -> Optional[Verdict]:
        ring = self._rings.get(uid)
        return Verdict(*ring[-1]) if ring else None

    def tasks(self) -> List[int]:
        return list(self._rings)

    def clear(self) -> None:
        self._rings.clear()
        self._names.clear()

    def __len__(self) -> int:
        return len(self._rings)

    def __repr__(self) -> str:
        return (f"Explainer(per_task={self.per_task}, "
                f"tasks={len(self._rings)}, recorded={self.recorded})")


def attach_explainer(sched: Any, explainer: Explainer) -> Explainer:
    """Point every decision site of ``sched`` at ``explainer``.

    Mirrors ``attach_tracer``: a flat/gang/preemptive scheduler gets
    ``_explain`` set directly; a ``ShardedScheduler`` fans out to every
    shard and (re)stamps each shard's global ``_trace_dev_off`` device
    base — either attacher may run first, both agree on the offsets. The
    clock is late-bound through ``sched._clock`` like the tracer's.
    """
    shards = getattr(sched, "shards", None)
    if shards is not None:
        sched._explain = explainer               # wrapper-level verdicts
        off = 0
        for sh in shards:
            sh._explain = explainer
            sh._trace_dev_off = off
            off += len(sh.devices)
    else:
        sched._explain = explainer
    explainer.use_clock_host(sched)
    return explainer


def format_verdicts(verdicts: List[Verdict]) -> str:
    """Human-readable one-line-per-verdict rendering (used by
    ``examples/trace_viewer.py``'s explain epilogue and ``repro-top``)."""
    lines = []
    for v in verdicts:
        rep = f" x{v.repeats}" if v.repeats > 1 else ""
        dev = f" dev={v.device}" if v.device >= 0 else ""
        why = ""
        if v.reasons:
            parts = []
            for r in v.reasons[:4]:
                extra = {k: w for k, w in r.items()
                         if k not in ("reason", "device")}
                tag = r.get("reason", "?")
                if "device" in r:
                    tag += f"@dev{r['device']}"
                if extra:
                    tag += "(" + ", ".join(f"{k}={w}" for k, w in
                                           sorted(extra.items())) + ")"
                parts.append(tag)
            if len(v.reasons) > 4:
                parts.append(f"... +{len(v.reasons) - 4} more")
            why = "  [" + "; ".join(parts) + "]"
        lines.append(f"  t={v.t:9.4f}  {v.action:<16}{rep}{dev}{why}")
    return "\n".join(lines) if lines else "  (no verdicts recorded)"
