"""Chrome/Perfetto trace-event export of a lifecycle event stream.

``to_chrome_trace`` folds a ``Tracer.events()`` window into the Chrome
trace-event JSON format (load in Perfetto / ``chrome://tracing``):

  * one **process row per device** ("device N") whose "X" complete slices
    are resource occupancy — a task holds the device from ADMIT/GROW to
    the matching END/SHRINK/EVICT/CRASH;
  * a **counter track** ("waiters") tracking admission-queue depth,
    reconstructed from PARK/REQUEUE vs. ADMIT/GROW/SHED/CRASH/STEAL (a
    RESTOREd steal re-parks on its owner);
  * **flow arrows** stitching one task's consecutive occupancy slices —
    an evicted/migrated task's park→readmit arc draws as an arrow from
    the old device's slice to the new one's;
  * instant markers for fleet events (MARK_DEAD/REVIVE).

Timestamps are microseconds relative to the window's first event, which
keeps virtual-clock (seconds-scale) and wall-clock (monotonic-origin)
streams equally readable.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import events as ev

# kinds that OPEN a device-occupancy slice / CLOSE one
_OPENS = (ev.ADMIT, ev.GROW)
_CLOSES = (ev.END, ev.SHRINK, ev.EVICT, ev.CRASH)
# kinds that add to / remove from the parked-waiter population
_PARKS = (ev.PARK, ev.REQUEUE, ev.RESTORE)
_UNPARKS = (ev.ADMIT, ev.GROW, ev.SHED, ev.CRASH, ev.STEAL)

_QUEUE_PID = 1_000_000  # synthetic process row for the counter track


def device_track_name(d: int, devices_per_pod: Optional[int] = None) -> str:
    """Display name for device ``d``'s process row. With
    ``devices_per_pod`` (a sharded/multi-pod fleet) the flat global index
    is factored into ``pod{p}/dev{d}`` so Perfetto groups tracks by pod;
    a flat fleet keeps the historical ``device N``."""
    if devices_per_pod and devices_per_pod > 0:
        return f"pod{d // devices_per_pod}/dev{d % devices_per_pod}"
    return f"device {d}"


def to_chrome_trace(events: Sequence[ev.Event], *,
                    devices_per_pod: Optional[int] = None,
                    profile_counters: bool = False) -> dict:
    """Fold an event window into a Chrome trace-event document (dict).

    ``profile_counters`` merges the profiling plane's counter tracks
    (per-device "occupancy %" on each device row, and a fleet-wide
    "prediction error %" row) built by ``obs.profile`` from the same
    window — off by default so uncalibrated exports are byte-identical
    to the historical format."""
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(e.t for e in events)

    def us(t: float) -> float:
        return round((t - t0) * 1e6, 3)

    out: List[dict] = []
    devices = sorted({e.device for e in events if e.device >= 0})
    for d in devices:
        out.append({"ph": "M", "pid": d, "tid": 0, "name": "process_name",
                    "args": {"name": device_track_name(d, devices_per_pod)}})
    out.append({"ph": "M", "pid": _QUEUE_PID, "tid": 0,
                "name": "process_name", "args": {"name": "scheduler queue"}})

    # -- occupancy slices + flows ------------------------------------------
    open_slice: Dict[int, Tuple[float, int, str]] = {}  # uid -> (t, dev, nm)
    closed: Dict[int, List[dict]] = {}                  # uid -> its slices
    for e in events:
        if e.kind in _OPENS and e.uid >= 0 and e.device >= 0:
            # re-admission with a still-open slice (shouldn't happen on a
            # sound stream, but an overwritten ring can lose the close):
            # close the stale one at the new open to keep the JSON valid
            if e.uid in open_slice:
                _close(open_slice, closed, e.uid, e.t, "lost-close", us)
            open_slice[e.uid] = (e.t, e.device, e.name or f"task {e.uid}")
        elif e.kind in _CLOSES and e.uid in open_slice:
            _close(open_slice, closed, e.uid, e.t, e.kind, us)
        elif e.kind in (ev.MARK_DEAD, ev.REVIVE) and e.device >= 0:
            out.append({"ph": "i", "s": "g", "pid": e.device, "tid": 0,
                        "name": e.kind, "ts": us(e.t)})
    t_end = max(e.t for e in events)
    for uid in list(open_slice):                 # still running at the end
        _close(open_slice, closed, uid, t_end, "open", us)
    flows = 0
    for uid, slices in closed.items():
        out.extend(slices)
        # one flow arrow per consecutive slice pair: the park→readmit arc
        # of an evicted/migrated task, drawn across devices when they moved
        for a, b in zip(slices, slices[1:]):
            out.append({"ph": "s", "id": uid, "cat": "task-flow",
                        "name": "resume", "pid": a["pid"], "tid": uid,
                        "ts": a["ts"] + a["dur"]})
            out.append({"ph": "f", "bp": "e", "id": uid, "cat": "task-flow",
                        "name": "resume", "pid": b["pid"], "tid": uid,
                        "ts": b["ts"]})
            flows += 1

    # -- waiter-depth counter ----------------------------------------------
    # Coalesced: a park+admit pair at one timestamp collapses to its final
    # depth (keep-last per ts), and a sample equal to the last emitted
    # depth is skipped entirely — a steal/restore churn that nets to zero
    # adds NO counter rows instead of a same-value sawtooth.
    parked: set = set()
    samples: List[Tuple[float, int]] = []
    for e in events:
        if e.uid < 0:
            continue
        n0 = len(parked)
        if e.kind in _PARKS:
            parked.add(e.uid)
        elif e.kind in _UNPARKS:
            parked.discard(e.uid)
        if len(parked) != n0:
            ts = us(e.t)
            if samples and samples[-1][0] == ts:
                samples[-1] = (ts, len(parked))
            else:
                samples.append((ts, len(parked)))
    last_depth: Optional[int] = None
    for ts, depth in samples:
        if depth == last_depth:
            continue
        last_depth = depth
        out.append({"ph": "C", "pid": _QUEUE_PID, "name": "waiters",
                    "ts": ts, "args": {"depth": depth}})

    # -- profiling-plane counters (lazy import: profile builds ON export) ---
    if profile_counters:
        from repro.obs.profile import chrome_counter_records
        out.extend(chrome_counter_records(events, us))

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def _close(open_slice: dict, closed: dict, uid: int, t: float,
           why: str, us) -> None:
    t_open, dev, name = open_slice.pop(uid)
    closed.setdefault(uid, []).append({
        "ph": "X", "pid": dev, "tid": uid, "name": name,
        "cat": "occupancy", "ts": us(t_open),
        "dur": max(round((t - t_open) * 1e6, 3), 0.0),
        "args": {"uid": uid, "end": why}})


def write_chrome_trace(events: Sequence[ev.Event], path: str, *,
                       devices_per_pod: Optional[int] = None,
                       profile_counters: bool = False) -> dict:
    """Export ``events`` to a Perfetto-loadable JSON file; returns the
    document so callers can validate/summarize without re-reading it."""
    doc = to_chrome_trace(events, devices_per_pod=devices_per_pod,
                          profile_counters=profile_counters)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# -- validation --------------------------------------------------------------

_KNOWN_PH = frozenset("XBEiMsfC")


def validate_chrome_trace(doc: dict) -> List[str]:
    """Structural validation against the Chrome trace-event format.
    Returns a list of problems (empty == valid): every record needs a
    known ``ph``; "X" slices need pid/ts/dur with dur >= 0; flow starts
    and finishes must pair up by id."""
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    flow_s: Dict[int, int] = {}
    flow_f: Dict[int, int] = {}
    track_names: Dict[str, int] = {}   # process_name -> first pid
    for i, r in enumerate(evs):
        ph = r.get("ph")
        if ph not in _KNOWN_PH:
            problems.append(f"[{i}] unknown ph {ph!r}")
            continue
        if ph == "M" and r.get("name") == "process_name":
            # two process rows sharing one display name render as ONE
            # merged track in Perfetto — pod-qualified names must be
            # unique per pid (the sharded-fleet regression this guards)
            nm = (r.get("args") or {}).get("name")
            pid = r.get("pid")
            if nm in track_names and track_names[nm] != pid:
                problems.append(
                    f"[{i}] duplicate track name {nm!r} for pid {pid} "
                    f"(already names pid {track_names[nm]})")
            elif nm is not None:
                track_names[nm] = pid
        if ph == "X":
            if not all(k in r for k in ("pid", "ts", "dur", "name")):
                problems.append(f"[{i}] X slice missing pid/ts/dur/name")
            elif r["dur"] < 0:
                problems.append(f"[{i}] X slice negative dur {r['dur']}")
        elif ph == "C":
            if "args" not in r or not isinstance(r["args"], dict):
                problems.append(f"[{i}] counter without args dict")
        elif ph == "s":
            flow_s[r.get("id")] = flow_s.get(r.get("id"), 0) + 1
        elif ph == "f":
            flow_f[r.get("id")] = flow_f.get(r.get("id"), 0) + 1
    for fid, n in flow_s.items():
        if flow_f.get(fid, 0) != n:
            problems.append(f"flow id {fid}: {n} start(s), "
                            f"{flow_f.get(fid, 0)} finish(es)")
    for fid in flow_f:
        if fid not in flow_s:
            problems.append(f"flow id {fid}: finish without start")
    return problems


def trace_summary(doc: dict) -> dict:
    """Quick stats for assertions: device process rows, slice count, flow
    count, and how many flows CROSS devices (the migrated-task arrows the
    acceptance gate wants at least one of)."""
    evs = doc.get("traceEvents", [])
    devices = sorted({r["pid"] for r in evs
                      if r.get("ph") == "X" and isinstance(r.get("pid"), int)})
    slices = sum(1 for r in evs if r.get("ph") == "X")
    # flows were emitted strictly as an s/f pair per arc, in order — pair
    # them back up by id and order of appearance
    by_id_s: Dict[int, List[dict]] = {}
    by_id_f: Dict[int, List[dict]] = {}
    for r in evs:
        if r.get("ph") == "s":
            by_id_s.setdefault(r["id"], []).append(r)
        elif r.get("ph") == "f":
            by_id_f.setdefault(r["id"], []).append(r)
    flows = cross = 0
    for fid, ss in by_id_s.items():
        for s, f in zip(ss, by_id_f.get(fid, [])):
            flows += 1
            if s.get("pid") != f.get("pid"):
                cross += 1
    return {"devices": devices, "slices": slices,
            "flows": flows, "cross_device_flows": cross,
            "counter_samples": sum(1 for r in evs if r.get("ph") == "C")}
