"""Live SLO / degradation monitoring over the metrics plane.

The paper's headline quality claim is an execution-dilation envelope:
compiler-guided sharing keeps per-kernel slowdown within ~2.5% of the
solo roofline while sharing the chip. This module turns that number —
plus the serving-path deadline/TTFT/TPOT targets — into *live* rolling
state with alert callbacks, instead of a post-hoc notebook:

  * ``SLOMonitor`` keeps bounded rolling windows (deadline-met flags,
    TTFT/TPOT samples, per-task observed-vs-roofline slowdown) and
    computes **burn rates**: the fraction of the window violating the
    objective divided by the error budget ``1 - target``. Burn > 1
    means the window is spending budget faster than the SLO allows;
    crossing 1 upward fires the alert hook exactly once per violation
    episode (healthy -> violating transition), so an operator hears
    about a regression when it starts, not 400 times while it lasts.
  * The paper's 2.5% envelope (``SLOWDOWN_ENVELOPE``) is the default
    alert threshold for the slowdown stream: a task whose observed
    duration exceeds roofline x (1 + envelope) is a violation.
  * ``SLOMonitor.for_serving`` subscribes the monitor to a
    ``MetricsRegistry``'s ``ttft_s`` / ``tpot_s`` histograms via the
    registry's ``on_record`` observer hook — the serve engine's existing
    metric writes feed the monitor with no new instrumentation.
  * ``prometheus_text`` renders a registry snapshot (and optionally a
    monitor's status) in the Prometheus text exposition format, so a
    scrape endpoint is one ``web.Response(text=...)`` away.
"""
from __future__ import annotations

import re
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, NamedTuple, Optional

# The paper's execution-dilation envelope (§V-B: MGB keeps per-kernel
# slowdown within ~2.5% of solo) — the default degradation threshold.
SLOWDOWN_ENVELOPE = 0.025


class SLOAlert(NamedTuple):
    """One healthy->violating transition."""
    t: float
    stream: str        # "deadline" | "ttft" | "tpot" | "slowdown" | "drift"
    name: str          # task name for slowdown/drift alerts, else ""
    value: float       # the burn rate (or slowdown factor) at transition
    threshold: float   # what it crossed


class _Window:
    """Rolling boolean window: violation flags + O(1) burn rate."""

    __slots__ = ("flags", "violations", "target")

    def __init__(self, window: int, target: float):
        self.flags: Deque[bool] = deque(maxlen=window)
        self.violations = 0
        self.target = target

    def push(self, violated: bool) -> None:
        if len(self.flags) == self.flags.maxlen and self.flags[0]:
            self.violations -= 1
        self.flags.append(violated)
        if violated:
            self.violations += 1

    @property
    def rate(self) -> float:
        return self.violations / len(self.flags) if self.flags else 0.0

    @property
    def burn(self) -> float:
        """Violation rate over the error budget: > 1 = burning faster
        than the SLO allows."""
        budget = max(1.0 - self.target, 1e-9)
        return self.rate / budget


class SLOMonitor:
    """Rolling-window SLO state with edge-triggered alert callbacks.

    Feed it observations (``note_*``) from any thread; read ``status()``
    / ``alerts`` from a dashboard. All windows are bounded deques — a
    serving fleet can stream forever without growth.
    """

    def __init__(self, *, window: int = 256,
                 deadline_target: float = 0.95,
                 ttft_slo_s: Optional[float] = None,
                 tpot_slo_s: Optional[float] = None,
                 latency_target: float = 0.99,
                 slowdown_envelope: float = SLOWDOWN_ENVELOPE,
                 drift_tolerance: float = 0.25,
                 drift_target: float = 0.9,
                 on_alert: Optional[Callable[[SLOAlert], None]] = None,
                 clock: Optional[Callable[[], float]] = None):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s
        self.slowdown_envelope = slowdown_envelope
        # probe-drift stream (fed by obs.calibrate via for_calibration): a
        # completion whose observed/predicted runtime ratio strays more than
        # drift_tolerance from 1 is a drift violation; the stream burning
        # past its (looser) drift_target budget means the workload has
        # drifted away from what the probes predict
        self.drift_tolerance = drift_tolerance
        self.on_alert = on_alert
        self._clock = clock or time.monotonic
        self._wins: Dict[str, _Window] = {
            "deadline": _Window(window, deadline_target),
            "ttft": _Window(window, latency_target),
            "tpot": _Window(window, latency_target),
            "slowdown": _Window(window, latency_target),
            "drift": _Window(window, drift_target),
        }
        self._violating: Dict[str, bool] = {k: False for k in self._wins}
        # per-task latest slowdown factor (observed / roofline)
        self.slowdowns: Dict[str, float] = {}
        self.alerts: List[SLOAlert] = []

    # -- observations --------------------------------------------------------
    def _push(self, stream: str, violated: bool, value: float,
              threshold: float, name: str = "") -> None:
        win = self._wins[stream]
        win.push(violated)
        burning = win.burn > 1.0
        was = self._violating[stream]
        self._violating[stream] = burning
        if burning and not was:
            alert = SLOAlert(self._clock(), stream, name,
                             value if stream == "slowdown" else win.burn,
                             threshold)
            self.alerts.append(alert)
            if self.on_alert is not None:
                self.on_alert(alert)

    def note_deadline(self, met: bool) -> None:
        """One job resolved with a deadline: did it make it?"""
        self._push("deadline", not met, 0.0, 1.0)

    def note_ttft(self, seconds: float) -> None:
        slo = self.ttft_slo_s
        self._push("ttft", slo is not None and seconds > slo,
                   seconds, slo or 0.0)

    def note_tpot(self, seconds: float) -> None:
        slo = self.tpot_slo_s
        self._push("tpot", slo is not None and seconds > slo,
                   seconds, slo or 0.0)

    def note_slowdown(self, name: str, observed_s: float,
                      roofline_s: float) -> None:
        """Observed wall duration vs the solo roofline estimate: factor
        above ``1 + envelope`` is a degradation violation (the paper's
        2.5% claim, live)."""
        factor = observed_s / roofline_s if roofline_s > 0 else 1.0
        self.note_slowdown_factor(name, factor)

    def note_slowdown_factor(self, name: str, factor: float) -> None:
        self.slowdowns[name] = factor
        limit = 1.0 + self.slowdown_envelope
        self._push("slowdown", factor > limit, factor, limit, name)

    def note_drift(self, name: str, predicted_s: float,
                   observed_s: float) -> None:
        """One completion's predicted-vs-observed runtime: a ratio straying
        more than ``drift_tolerance`` from 1 (either direction) counts as
        probe drift. Edge-triggered like every stream — the alert fires
        once when the window starts burning, i.e. when mispredictions
        become the norm rather than noise."""
        if predicted_s <= 0:
            return
        ratio = observed_s / predicted_s
        self._push("drift", abs(ratio - 1.0) > self.drift_tolerance,
                   ratio, self.drift_tolerance, name)

    # -- registry subscription ----------------------------------------------
    @classmethod
    def for_serving(cls, registry: Any, **kw) -> "SLOMonitor":
        """Build a monitor subscribed to the serving metrics a
        ``MetricsRegistry`` already collects: every ``ttft_s`` /
        ``tpot_s`` histogram record feeds the rolling windows via the
        registry's ``on_record`` hook."""
        mon = cls(**kw)
        registry.on_record("ttft_s", mon.note_ttft)
        registry.on_record("tpot_s", mon.note_tpot)
        return mon

    @classmethod
    def for_calibration(cls, store: Any, **kw) -> "SLOMonitor":
        """Build a monitor whose drift stream is fed by a
        ``CalibrationStore``: every completion observation the store
        records (via its ``on_observe`` hook) compares the ORIGINAL probe
        estimate against the observed runtime — corrected estimates are
        deliberately not used, so the alert tracks raw probe quality even
        while calibration is hiding the error from admission."""
        mon = cls(**kw)

        def feed(o: Any) -> None:
            if o.observed_s is not None:
                mon.note_drift(o.name, o.predicted_s, o.observed_s)

        store.on_observe(feed)
        return mon

    # -- reading -------------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        """One dict a dashboard renders directly: per-stream window
        size, violation rate, burn rate, healthy flag; plus the worst
        current slowdown."""
        out: Dict[str, Any] = {}
        for k, w in self._wins.items():
            out[k] = {"n": len(w.flags), "rate": w.rate, "burn": w.burn,
                      "healthy": not self._violating[k]}
        worst = max(self.slowdowns.items(), key=lambda kv: kv[1],
                    default=None)
        out["worst_slowdown"] = \
            {"name": worst[0], "factor": worst[1]} if worst else None
        out["alerts"] = len(self.alerts)
        return out

    @property
    def healthy(self) -> bool:
        return not any(self._violating.values())


# -- Prometheus text exposition ----------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str, prefix: str = "repro_") -> str:
    return prefix + _NAME_RE.sub("_", name)


def prometheus_text(registry: Any,
                    monitor: Optional[SLOMonitor] = None,
                    *, prefix: str = "repro_") -> str:
    """Render a ``MetricsRegistry`` snapshot (plus, optionally, an
    ``SLOMonitor``'s status) in the Prometheus text exposition format:
    counters as ``_total``, gauges bare, histograms as summaries
    (quantile-labelled samples + ``_sum``/``_count``)."""
    snap = registry.snapshot()
    lines: List[str] = []
    for name, value in snap.get("counters", {}).items():
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m}_total counter")
        lines.append(f"{m}_total {value}")
    for name, value in snap.get("gauges", {}).items():
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {value}")
    for name, h in snap.get("histograms", {}).items():
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m} summary")
        for q, key in (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99")):
            lines.append(f'{m}{{quantile="{q}"}} {h[key]}')
        lines.append(f"{m}_sum {h['mean'] * h['n']}")
        lines.append(f"{m}_count {h['n']}")
    if monitor is not None:
        st = monitor.status()
        for stream in ("deadline", "ttft", "tpot", "slowdown", "drift"):
            s = st[stream]
            m = _metric_name(f"slo_{stream}_burn", prefix)
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {s['burn']}")
            m = _metric_name(f"slo_{stream}_healthy", prefix)
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {int(s['healthy'])}")
        m = _metric_name("slo_alerts", prefix)
        lines.append(f"# TYPE {m}_total counter")
        lines.append(f"{m}_total {st['alerts']}")
    return "\n".join(lines) + "\n"
