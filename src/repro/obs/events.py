"""Lifecycle event schema + the bounded lock-light ring-buffer ``Tracer``.

Every decision the scheduler stack makes — park, admit, evict, grow,
steal, … — is one immutable ``Event`` carrying a monotonic sequence
number and a timestamp on the BACKEND's timeline (wall monotonic for the
live executor, the virtual clock for the simulator; ``Tracer.use_clock``
rebinding follows whichever one currently drives ``sched._clock``).

Design constraints, in order:

  1. **Disabled must be free.** Emission sites guard with
     ``tr = self._trace`` / ``if tr is not None`` — one attribute load on
     the hot admission path when tracing is off (the PR-6 scale numbers
     must survive).
  2. **Enabled must be cheap and never block.** ``emit`` allocates one
     plain tuple and appends it to a ``deque(maxlen=capacity)`` — the
     ring stores raw tuples and ``events()`` materializes ``Event``s
     lazily, because the NamedTuple constructor's kwarg/default machinery
     alone costs more than the rest of the emission path combined. The
     sequence counter is ``itertools.count`` (atomic under the GIL), the
     ring append is one C call that also evicts the oldest entry — no
     lock, safe against the live backend's concurrent emitters. A
     saturated ring drops the oldest entries and counts them in
     ``dropped`` instead of stalling anyone.
  3. **Immutable events.** NamedTuple on the read side: impossible to
     mutate after the fact, trivially comparable in parity diffs, and
     field-for-field identical to the raw tuple the ring recorded.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Callable, Deque, List, NamedTuple, Optional

# -- event kinds ------------------------------------------------------------
# One constant per lifecycle transition. String values (not ints) so dumps
# and diffs read directly; identity comparison still works because every
# emitter uses these module constants.
SUBMIT = "submit"              # task handed to the admission path
PARK = "park"                  # parked in the waiter queue
ADMIT = "admit"                # resources granted on a device
DISPATCH = "dispatch"          # live backend: handed to an execution worker
BEGIN = "begin"                # execution started
END = "end"                    # resources released on completion
EVICT = "evict"                # preempted / device-death victim
REQUEUE = "requeue"            # evicted task re-parked (restart priority)
GROW = "grow"                  # decode-slot delta admitted onto a resident
SHRINK = "shrink"              # grown delta released
GANG_RESERVE = "gang_reserve"  # k-chip group atomically reserved
GANG_RELEASE = "gang_release"  # gang group released
MARK_DEAD = "mark_dead"        # device/cell declared dead
REVIVE = "revive"              # device/cell back in service
STEAL = "steal"                # sharded: waiter stolen toward an idle pod
RESTORE = "restore"            # sharded: refused steal returned to owner
SHED = "shed"                  # parked past its deadline, failed at a drain
CRASH = "crash"                # OOM / infeasible / runner exception

ALL_KINDS = frozenset({
    SUBMIT, PARK, ADMIT, DISPATCH, BEGIN, END, EVICT, REQUEUE, GROW,
    SHRINK, GANG_RESERVE, GANG_RELEASE, MARK_DEAD, REVIVE, STEAL,
    RESTORE, SHED, CRASH,
})


class Event(NamedTuple):
    """One immutable lifecycle record.

    ``seq``    — monotonic per-tracer sequence number (decision order;
                 timestamps may tie, seq never does).
    ``t``      — backend-timeline seconds (wall monotonic or virtual).
    ``kind``   — one of the module constants above.
    ``uid``    — task uid (-1 for fleet events like mark_dead/revive).
    ``name``   — task name ("" when not task-scoped). Parity diffs compare
                 names, not uids: re-built Jobs get fresh uids per leg.
    ``device`` — GLOBAL flat device index (-1 when placement-free; sharded
                 schedulers offset shard-local indices by the shard base).
    ``epoch``  — admission epoch of the task at emission time (fences
                 stale observations exactly as the scheduler's own do).
    ``data``   — optional dict of kind-specific extras (cause, peer uid,
                 shard ids, reserved gang devices, ...).
    """
    seq: int
    t: float
    kind: str
    uid: int = -1
    name: str = ""
    device: int = -1
    epoch: int = 0
    data: Optional[dict] = None


class Tracer:
    """Bounded ring buffer of ``Event``s, lock-light and drop-counting.

    ``emit`` is safe from any thread: the sequence counter is atomic under
    the GIL and the ring is a ``deque(maxlen=capacity)`` whose C-level
    ``append`` both inserts and evicts the oldest entry in one atomic
    step. Two racing emitters may append out of sequence order (each takes
    its number, then appends); ``events()`` sorts by seq on read. When
    more than ``capacity`` events arrive the oldest are dropped and
    counted in ``dropped`` — a flight recorder keeps the most recent
    window, never blocks the scheduler, and never grows without bound.

    ``emit`` is a per-instance closure built at construction time with the
    ring's ``append``, the counter, and the clock prebound as locals: on
    the measured admission hot path every ``self.`` attribute load is a
    visible fraction of the per-event budget (see benchmarks/bench_obs).
    ``enabled`` is therefore fixed at construction — ``enabled=False``
    installs a no-op closure (callers that hold ``_trace = None`` never
    even reach that).
    """

    def __init__(self, capacity: int = 1 << 16, *,
                 clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self._clock: Callable[[], float] = clock or time.monotonic
        self._clock_host: Optional[Any] = None
        # ring slots hold RAW tuples (same field order as Event); they are
        # promoted to Event only on read — emit stays allocation-minimal
        self._dq: Deque[tuple] = deque(maxlen=capacity)
        self._count = itertools.count()
        self._cleared = 0                # emitted total at the last clear()
        self.emit = self._build_emit()

    # -- recording -----------------------------------------------------------
    def _build_emit(self) -> Callable[..., None]:
        """Build the instance's ``emit(kind, uid=-1, name="", device=-1,
        epoch=0, data=None)`` closure: records one event at the current
        backend time; never blocks, never raises on saturation (oldest
        entries are dropped)."""
        if not self.enabled:
            def emit_noop(kind: str, uid: int = -1, name: str = "",
                          device: int = -1, epoch: int = 0,
                          data: Optional[dict] = None) -> None:
                return None
            return emit_noop
        count = self._count
        append = self._dq.append         # clear() keeps the deque's identity
        host = self._clock_host
        clock = self._clock
        if host is not None:
            # host mode (attach_tracer): read the clock THROUGH the
            # scheduler each event — follows Simulator.reset's virtual
            # clock swap without paying a wrapping lambda per event
            def emit(kind: str, uid: int = -1, name: str = "",
                     device: int = -1, epoch: int = 0,
                     data: Optional[dict] = None) -> None:
                append((next(count), host._clock(), kind, uid, name,
                        device, epoch, data))
        else:
            def emit(kind: str, uid: int = -1, name: str = "",
                     device: int = -1, epoch: int = 0,
                     data: Optional[dict] = None) -> None:
                append((next(count), clock(), kind, uid, name, device,
                        epoch, data))
        return emit

    def use_clock(self, clock: Callable[[], float]) -> None:
        """Rebind the timestamp source to a callable (rebuilds the emit
        closure — the clock is prebound there)."""
        self._clock = clock
        self._clock_host = None
        self.emit = self._build_emit()

    def use_clock_host(self, host: Any) -> None:
        """Timestamp from ``host._clock()``, read through ``host`` on
        every event: ``attach_tracer`` binds the scheduler here so
        Simulator.reset's virtual-clock swap and Cluster-live's
        wall-clock restore are followed automatically, without a
        wrapping lambda on the emission hot path."""
        self._clock_host = host
        self._clock = lambda: host._clock()   # introspection/fallback
        self.emit = self._build_emit()

    # -- reading -------------------------------------------------------------
    @property
    def emitted(self) -> int:
        """Total events ever emitted (including dropped ones). Derived
        from the newest surviving seq so emit never pays a counter store;
        under racing emitters momentarily lower-bound (benign)."""
        dq = self._dq
        try:
            return dq[-1][0] + 1
        except IndexError:               # empty: nothing since last clear
            return self._cleared

    @property
    def dropped(self) -> int:
        """Events lost to ring eviction (0 until saturation)."""
        return max(0, self.emitted - self.capacity)

    def events(self) -> List[Event]:
        """Snapshot of the surviving window, in sequence order. Safe to
        call while emitters run (the deque is copied first; racing
        appends at worst miss the snapshot or land slightly out of
        insertion order, which the seq sort repairs)."""
        mk = Event._make
        return sorted(map(mk, list(self._dq)), key=lambda e: e.seq)

    def clear(self) -> None:
        """Drop all recorded events; sequence numbers keep counting up
        (so a post-clear window still orders against nothing stale).
        In-place: the emit closure holds the deque by identity."""
        self._cleared = self.emitted
        self._dq.clear()

    def __len__(self) -> int:
        return len(self._dq)

    def __repr__(self) -> str:
        return (f"Tracer(capacity={self.capacity}, emitted={self.emitted}, "
                f"dropped={self.dropped}, enabled={self.enabled})")


def submit_data(task: Any, job_name: str, job_uid: int) -> dict:
    """Build the SUBMIT event's data payload.

    Carries everything a counterfactual replay needs to reconstruct the
    submission (``obs.whatif``): the job identity, the task's admission
    class (priority / absolute deadline / gang label) and its full
    resource vector. Duck-typed on ``Task`` so the obs package keeps its
    no-core-imports rule; both backends call this at their (cold,
    per-task) submit sites.
    """
    r = task.resources
    return {
        "job": job_name,
        "job_uid": job_uid,
        "priority": task.priority,
        "deadline_t": task.deadline_t,
        "gang_id": task.gang_id,
        "hbm_bytes": r.hbm_bytes,
        "flops": r.flops,
        "bytes_accessed": r.bytes_accessed,
        "collective_bytes": r.collective_bytes,
        "est_seconds": r.est_seconds,
        "core_demand": r.core_demand,
        "bw_demand": r.bw_demand,
        "chips": r.chips,
    }


def attach_tracer(sched: Any, tracer: Tracer) -> Tracer:
    """Point every emission site of ``sched`` at ``tracer``.

    Works on any scheduler class: a flat/gang/preemptive scheduler gets
    ``_trace`` set directly; a ``ShardedScheduler`` fans out to every
    shard, also stamping each shard's ``_trace_dev_off`` with its global
    flat-device base so shard-local indices land as fleet-global ones in
    the event stream. The tracer's clock is late-bound through
    ``sched._clock`` so backend swaps (sim virtual time vs. live wall
    time) are followed without re-attachment.
    """
    shards = getattr(sched, "shards", None)
    if shards is not None:
        sched._trace = tracer                     # wrapper-level events
        off = 0
        for sh in shards:
            sh._trace = tracer
            sh._trace_dev_off = off
            off += len(sh.devices)
    else:
        sched._trace = tracer
    tracer.use_clock_host(sched)
    return tracer
