"""Log-bucketed histograms + counter/gauge registry with JSON snapshots.

Serving-path metrics (queueing delay, TTFT, TPOT, eviction cost, steal
rate) span five orders of magnitude — linear buckets would either blur
the tail or explode in count. ``Histogram`` buckets by powers of
``growth`` (default 2) from ``least`` upward: bucket *i* holds values in
``[least * growth**i, least * growth**(i+1))``, so p99 at 50 ms and p50
at 50 µs live in the same 40-bucket structure with bounded error.

``MetricsRegistry`` is the named collection point: ``hist/counter/gauge``
get-or-create, ``snapshot()`` is a plain-dict view, ``save_json`` writes
it. ``metrics_from_events`` derives the standard scheduler metrics from
an ``obs.events`` stream, so a traced run gets histograms for free.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence

from repro.obs import events as ev


class Histogram:
    """Log-bucketed histogram: O(1) record, bounded memory, quantiles with
    one-bucket resolution. Values below ``least`` land in bucket 0;
    values past the last bucket clamp into it (and are counted exactly in
    ``overflow``)."""

    def __init__(self, *, least: float = 1e-6, growth: float = 2.0,
                 buckets: int = 48):
        if least <= 0 or growth <= 1 or buckets < 1:
            raise ValueError("need least > 0, growth > 1, buckets >= 1")
        self.least = least
        self.growth = growth
        self._log_g = math.log(growth)
        self.counts = [0] * buckets
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.overflow = 0
        # streaming observers (obs.slo alert hooks): called with each
        # recorded value. Empty list costs one truthiness check per record.
        self.observers: List = []

    def record(self, value: float) -> None:
        self.n += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        if value < self.least:
            i = 0
        else:
            i = int(math.log(value / self.least) / self._log_g) + 1
            if i >= len(self.counts):
                i = len(self.counts) - 1
                self.overflow += 1
        self.counts[i] += 1
        obs_fns = self.observers
        if obs_fns:
            for fn in obs_fns:
                fn(value)

    def bucket_bounds(self, i: int) -> tuple:
        """(lo, hi) of bucket ``i`` (bucket 0 is [0, least))."""
        if i == 0:
            return (0.0, self.least)
        return (self.least * self.growth ** (i - 1),
                self.least * self.growth ** i)

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0 when
        empty) — one-bucket resolution, monotone in q."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return min(self.bucket_bounds(i)[1], self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def snapshot(self) -> dict:
        return {
            "n": self.n, "mean": self.mean,
            "min": self.min if self.n else 0.0,
            "max": self.max if self.n else 0.0,
            "p50": self.quantile(0.50), "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "least": self.least, "growth": self.growth,
            "overflow": self.overflow,
            # sparse encoding: most of the 48 buckets are empty
            "buckets": {i: c for i, c in enumerate(self.counts) if c},
        }


class Counter:
    """Monotone event count."""

    def __init__(self) -> None:
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> float:
        return self.value


class MetricsRegistry:
    """Named get-or-create collection of histograms/counters/gauges."""

    def __init__(self) -> None:
        self._hists: Dict[str, Histogram] = {}
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}

    def hist(self, name: str, **kw) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(**kw)
        return h

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def on_record(self, name: str, fn) -> None:
        """Subscribe ``fn(value)`` to every future record on histogram
        ``name`` (get-or-create) — the live-alert hook ``obs.slo`` uses
        to watch TTFT/TPOT streams without polling snapshots."""
        self.hist(name).observers.append(fn)

    def snapshot(self) -> dict:
        return {
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self._hists.items())},
            "counters": {k: c.snapshot()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.snapshot()
                       for k, g in sorted(self._gauges.items())},
        }

    def save_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1)
        return snap


def metrics_from_events(events: Sequence[ev.Event],
                        reg: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Derive the standard scheduler metrics from a lifecycle stream:

      * ``queueing_delay_s`` — first park → first admission, per task;
      * ``eviction_cost_s``  — admission → eviction (work at risk), per
        evicted incarnation;
      * ``requeue_to_resume_s`` — eviction → re-admission;
      * counters: one per event kind, plus ``migrations`` (re-admission
        on a different device than the evicted incarnation).
    """
    reg = reg or MetricsRegistry()
    parked_at: Dict[int, float] = {}
    admitted_at: Dict[int, float] = {}
    admitted_dev: Dict[int, int] = {}
    evicted_at: Dict[int, float] = {}
    evicted_dev: Dict[int, int] = {}
    for e in events:
        reg.counter(f"events.{e.kind}").inc()
        if e.kind in (ev.PARK, ev.REQUEUE):
            parked_at.setdefault(e.uid, e.t)
        elif e.kind in (ev.ADMIT, ev.GROW):
            t_park = parked_at.pop(e.uid, None)
            if t_park is not None:
                reg.hist("queueing_delay_s").record(e.t - t_park)
            t_evict = evicted_at.pop(e.uid, None)
            if t_evict is not None:
                reg.hist("requeue_to_resume_s").record(e.t - t_evict)
                if evicted_dev.pop(e.uid, e.device) != e.device:
                    reg.counter("migrations").inc()
            admitted_at[e.uid] = e.t
            admitted_dev[e.uid] = e.device
        elif e.kind == ev.EVICT:
            t_adm = admitted_at.pop(e.uid, None)
            if t_adm is not None:
                reg.hist("eviction_cost_s").record(e.t - t_adm)
            evicted_at[e.uid] = e.t
            evicted_dev[e.uid] = admitted_dev.pop(e.uid, e.device)
    steals = reg.counter("events.steal").snapshot()
    admits = reg.counter("events.admit").snapshot()
    if admits:
        reg.gauge("steal_rate").set(steals / admits)
    return reg
