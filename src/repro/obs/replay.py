"""Flight recorder, sim/live parity differ, and lifecycle validator.

The repo's core determinism claim — one submission trace produces the
SAME decision sequence on the virtual-clock simulator and the threaded
live executor — has so far been asserted by four hand-rolled test
harnesses that each re-derive "the decision sequence" from a different
artifact (``sched.placements``, ``preempt_log``, ``join_log``). This
module promotes the pattern to a first-class tool over the one unified
artifact every backend now produces: the ``obs.events`` stream.

  * ``decisions``/``admission_order``/``eviction_order`` project a
    stream onto a comparable decision list (task NAMES, not uids — each
    leg rebuilds its Jobs and draws fresh uids);
  * ``first_divergence`` diffs two projections and pinpoints the first
    divergent decision with context (the actual parity differ);
  * ``validate_lifecycles`` checks every task's events walk a legal path
    through the lifecycle state machine — no lost, duplicated, or
    out-of-order transitions across eviction, pod death, grow/shrink,
    and work stealing;
  * ``FlightRecorder`` dumps the tracer's ring window to disk on crash
    or drain (wired into ``Cluster``), so a failed run leaves its last
    N decisions behind for post-mortem.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import events as ev

# -- decision projections ----------------------------------------------------


def decisions(events: Sequence[ev.Event], *, kinds: Sequence[str],
              with_device: bool = False) -> List:
    """Project a stream onto the ordered list of decisions of the given
    kinds, keyed by task name (uids differ between legs). With
    ``with_device`` each entry is ``(name, device)`` — only for decision
    kinds whose placement is itself deterministic."""
    want = frozenset(kinds)
    if with_device:
        return [(e.name, e.device) for e in events if e.kind in want]
    return [e.name for e in events if e.kind in want]


def admission_order(events: Sequence[ev.Event],
                    with_device: bool = False) -> List:
    """Names (or (name, device)) in admission order — ADMIT and GROW both
    count: a decode-slot join is an admission decision."""
    return decisions(events, kinds=(ev.ADMIT, ev.GROW),
                     with_device=with_device)


def eviction_order(events: Sequence[ev.Event],
                   with_device: bool = False) -> List:
    """Victim names in eviction order (preemptions and device deaths)."""
    return decisions(events, kinds=(ev.EVICT,), with_device=with_device)


@dataclasses.dataclass
class Divergence:
    """First point where two decision sequences disagree."""
    index: int
    a: object          # decision in stream A at index (None: A exhausted)
    b: object          # decision in stream B at index (None: B exhausted)
    a_context: List    # up to 3 decisions of A around the divergence
    b_context: List

    def __str__(self) -> str:
        return (f"decision #{self.index} diverges: "
                f"a={self.a!r} vs b={self.b!r} "
                f"(a context {self.a_context!r}, "
                f"b context {self.b_context!r})")


def first_divergence(a: Sequence, b: Sequence) -> Optional[Divergence]:
    """Diff two decision sequences; None iff identical. The returned
    ``Divergence`` prints usefully, so tests assert ``div is None, div``
    and a failure names the exact first divergent decision."""
    n = max(len(a), len(b))
    for i in range(n):
        da = a[i] if i < len(a) else None
        db = b[i] if i < len(b) else None
        if da != db:
            lo = max(i - 1, 0)
            return Divergence(i, da, db,
                              list(a[lo:i + 2]), list(b[lo:i + 2]))
    return None


def diff_streams(events_a: Sequence[ev.Event],
                 events_b: Sequence[ev.Event], *,
                 kinds: Sequence[str] = (ev.ADMIT, ev.GROW, ev.EVICT),
                 with_device: bool = False) -> Optional[Divergence]:
    """One-call parity differ: project both streams onto the given
    decision kinds and report the first divergent decision (None iff the
    runs agree). The default kinds cover the repo's determinism claim:
    admission order (incl. slot grows) and eviction order."""
    return first_divergence(
        decisions(events_a, kinds=kinds, with_device=with_device),
        decisions(events_b, kinds=kinds, with_device=with_device))


# -- lifecycle state machine -------------------------------------------------

# State names (internal to validation; events carry only kinds).
_NEW, _SUBMITTED, _PARKED, _ADMITTED, _RUNNING = \
    "new", "submitted", "parked", "admitted", "running"
_EVICTED, _STOLEN, _DONE, _DEAD = "evicted", "stolen", "done", "dead"

# state -> {event kind -> next state}. Kinds absent from a state's row are
# illegal there. Deliberate tolerances, each mirroring real backend
# behaviour rather than papering over bugs:
#   * DEAD -> PARKED: a sharded wrapper re-homes a waiter whose shard
#     declared it infeasible after local deaths (shard emits CRASH, the
#     wrapper re-parks it on a surviving shard);
#   * DONE -> DEAD: the live executor's OOM path releases resources
#     (task_end emits END) and THEN records the crash;
#   * EVICTED + GANG_RELEASE: a gang victim's group release may trail its
#     eviction notice.
_TRANSITIONS: Dict[str, Dict[str, str]] = {
    _NEW: {ev.SUBMIT: _SUBMITTED, ev.PARK: _PARKED,
           ev.ADMIT: _ADMITTED, ev.GROW: _ADMITTED},
    _SUBMITTED: {ev.PARK: _PARKED, ev.ADMIT: _ADMITTED,
                 ev.GROW: _ADMITTED, ev.CRASH: _DEAD},
    _PARKED: {ev.ADMIT: _ADMITTED, ev.GROW: _ADMITTED,
              ev.SHED: _DEAD, ev.CRASH: _DEAD, ev.STEAL: _STOLEN},
    _STOLEN: {ev.ADMIT: _ADMITTED, ev.RESTORE: _PARKED},
    _ADMITTED: {ev.DISPATCH: _ADMITTED, ev.GANG_RESERVE: _ADMITTED,
                ev.GANG_RELEASE: _ADMITTED, ev.BEGIN: _RUNNING,
                ev.END: _DONE, ev.SHRINK: _DONE,
                ev.EVICT: _EVICTED, ev.CRASH: _DEAD},
    _RUNNING: {ev.END: _DONE, ev.SHRINK: _DONE,
               ev.GANG_RELEASE: _RUNNING, ev.EVICT: _EVICTED,
               ev.CRASH: _DEAD},
    _EVICTED: {ev.REQUEUE: _PARKED, ev.GANG_RELEASE: _EVICTED},
    _DONE: {ev.GANG_RELEASE: _DONE, ev.CRASH: _DEAD},
    _DEAD: {ev.PARK: _PARKED},
}

TERMINAL_STATES = frozenset({_DONE, _DEAD})


def validate_lifecycles(events: Sequence[ev.Event],
                        *, require_terminal: bool = False) -> List[str]:
    """Walk every task's event sub-stream through the lifecycle state
    machine. Returns a list of violations (empty == sound): an illegal
    transition means a lost, duplicated, or out-of-order event. With
    ``require_terminal``, tasks left mid-flight at the end of the window
    are violations too (use after a full drain)."""
    state: Dict[int, str] = {}
    names: Dict[int, str] = {}
    problems: List[str] = []
    last_seq = -1
    for e in events:
        if e.seq <= last_seq:
            problems.append(f"seq not strictly increasing at {e!r}")
        last_seq = e.seq
        if e.uid < 0:
            if e.kind not in (ev.MARK_DEAD, ev.REVIVE):
                problems.append(f"task-scoped kind without uid: {e!r}")
            continue
        s = state.get(e.uid, _NEW)
        names.setdefault(e.uid, e.name)
        nxt = _TRANSITIONS.get(s, {}).get(e.kind)
        if nxt is None:
            problems.append(
                f"task {names[e.uid] or e.uid!r} (uid {e.uid}): illegal "
                f"{e.kind!r} in state {s!r} (seq {e.seq})")
            continue  # stay in s: report once, keep walking
        state[e.uid] = nxt
    if require_terminal:
        for uid, s in sorted(state.items()):
            if s not in TERMINAL_STATES:
                problems.append(f"task {names.get(uid) or uid!r} "
                                f"(uid {uid}) ended mid-flight in {s!r}")
    return problems


# -- flight recorder ---------------------------------------------------------


class FlightRecorder:
    """Dump the tracer's surviving ring window to disk on notable
    moments (crash, drain) — a post-mortem of the last N decisions.
    ``dump`` is idempotent per reason unless ``always=True``.

    Each dump also carries the tracer's drop counter (how much history
    the ring already lost — the post-mortem's own error bar) and a
    metrics snapshot: the attached ``registry``'s if one was passed,
    otherwise one derived from the surviving window itself
    (``metrics_from_events``) so a dump is never metric-less."""

    def __init__(self, tracer: ev.Tracer, path: str = "flight.json",
                 registry=None):
        self.tracer = tracer
        self.path = path
        self.registry = registry
        self.dumps: List[Tuple[str, str]] = []  # (reason, path)

    def dump(self, reason: str, *, always: bool = False) -> Optional[str]:
        if not always and any(r == reason for r, _ in self.dumps):
            return None
        base, ext = os.path.splitext(self.path)
        path = f"{base}.{reason}{ext or '.json'}" \
            if len(self.dumps) or always else self.path
        events = self.tracer.events()
        if self.registry is not None:
            metrics = self.registry.snapshot()
        else:
            from repro.obs.metrics import metrics_from_events
            metrics = metrics_from_events(events).snapshot()
        doc = {
            "reason": reason,
            "emitted": self.tracer.emitted,
            "dropped": self.tracer.dropped,
            "metrics": metrics,
            "events": [e._asdict() for e in events],
        }
        with open(path, "w") as f:
            json.dump(doc, f)
        self.dumps.append((reason, path))
        return path


def load_flight(path: str) -> List[ev.Event]:
    """Load a flight-recorder dump back into ``Event`` objects."""
    with open(path) as f:
        doc = json.load(f)
    return [ev.Event(**{**d, "data": d.get("data")})
            for d in doc["events"]]
