"""Per-task observed-vs-predicted attribution over the lifecycle stream.

``obs.events`` records what HAPPENED (park/admit/begin/end/evict, with
timestamps on the backend's own clock); the SUBMIT event's payload records
what the probe PREDICTED (the full resource vector). Joining the two turns
the tracer into a continuous profiler, with no new instrumentation on the
hot path:

  * ``TaskProfile`` — one record per task: predicted vs observed runtime
    (error seconds / ratio), memory reserved vs observed high-water,
    the queueing-delay decomposition (parked → dispatch → execution),
    eviction/incarnation counts;
  * ``profiles_from_events`` — the pure event-stream join (works on any
    recorded window, including a flight-recorder dump);
  * ``device_occupancy`` — per-device occupancy-percent timelines: the
    demand-weighted resident load reconstructed from ADMIT/GROW and
    END/SHRINK/EVICT/CRASH windows (demand from the SUBMIT payload);
  * ``chrome_counter_records`` — Perfetto counter tracks (per-device
    occupancy %, prediction-error %) merged into the Chrome export by
    ``obs.export`` when profile counters are requested;
  * ``Profiler`` — the live wrapper over a ``Tracer`` that
    ``Cluster.profile()`` / ``JobHandle.profile()`` read through.

Observed times come from the SAME events both backends already emit —
virtual-clock BEGIN→END spans in the simulator, wall-clock spans live —
so sim and live attribution are directly comparable (the parity test
diffs them through ``obs.replay``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs import events as ev

# fallback demand for residents that never passed a SUBMIT site (e.g. the
# serve engine's bind_resident decode-loop hosts): one compute slot's share
# of the scheduler's 16-slot ledger
DEFAULT_DEMAND = 1.0 / 16

_ERR_PID = 1_000_001   # synthetic process row for the prediction-error track


class TaskProfile:
    """Observed-vs-predicted attribution for one task uid."""

    __slots__ = ("uid", "name", "job", "pred_est_s", "pred_hbm", "demand",
                 "reserved_hbm", "hw_bytes", "submit_t", "park_s",
                 "dispatch_s", "exec_s", "end_t", "completed", "crashed",
                 "shed", "evictions", "incarnations", "devices", "grow",
                 "calibrated", "_park_at", "_admit_at", "_begin_at")

    def __init__(self, uid: int):
        self.uid = uid
        self.name = ""
        self.job = ""
        self.pred_est_s: Optional[float] = None   # probe estimate (SUBMIT)
        self.pred_hbm: Optional[int] = None
        self.demand: Optional[float] = None
        self.reserved_hbm: Optional[int] = None   # what admission granted
        self.hw_bytes: Optional[int] = None       # observed high-water (END)
        self.submit_t: Optional[float] = None
        self.park_s = 0.0        # parked in the waiter queue
        self.dispatch_s = 0.0    # admitted -> execution began
        self.exec_s = 0.0        # executing (sum over incarnations)
        self.end_t: Optional[float] = None
        self.completed = False
        self.crashed = False
        self.shed = False
        self.evictions = 0
        self.incarnations = 0    # ADMIT/GROW grants received
        self.devices: List[int] = []
        self.grow = False        # a decode-slot delta (GROW lifecycle)
        self.calibrated = False  # a corrected reservation was in effect
        self._park_at: Optional[float] = None
        self._admit_at: Optional[float] = None
        self._begin_at: Optional[float] = None

    # -- derived -------------------------------------------------------------
    @property
    def err_s(self) -> Optional[float]:
        """Observed minus predicted runtime (None without both sides, and
        meaningless for grow deltas, whose exec span is batch residency)."""
        if not self.completed or self.grow or self.pred_est_s is None \
                or self.exec_s <= 0.0:
            return None
        return self.exec_s - self.pred_est_s

    @property
    def err_ratio(self) -> Optional[float]:
        e = self.err_s
        if e is None or not self.pred_est_s:
            return None
        return e / self.pred_est_s

    @property
    def queueing_s(self) -> float:
        """Total pre-execution delay: parked + dispatch."""
        return self.park_s + self.dispatch_s

    @property
    def memory_violation(self) -> bool:
        """Observed high-water above the reservation — must never be True
        under a memory-safe scheduler + the calibration invariant."""
        return (self.hw_bytes is not None and self.reserved_hbm is not None
                and self.hw_bytes > self.reserved_hbm)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid, "name": self.name, "job": self.job,
            "pred_est_s": self.pred_est_s, "pred_hbm": self.pred_hbm,
            "reserved_hbm": self.reserved_hbm, "hw_bytes": self.hw_bytes,
            "park_s": self.park_s, "dispatch_s": self.dispatch_s,
            "exec_s": self.exec_s, "err_s": self.err_s,
            "err_ratio": self.err_ratio, "completed": self.completed,
            "crashed": self.crashed, "shed": self.shed,
            "evictions": self.evictions, "incarnations": self.incarnations,
            "devices": list(self.devices), "grow": self.grow,
            "calibrated": self.calibrated,
            "memory_violation": self.memory_violation,
        }

    def __repr__(self) -> str:
        return (f"TaskProfile({self.name or self.uid}, "
                f"pred={self.pred_est_s}, exec={self.exec_s:.4f}s, "
                f"park={self.park_s:.4f}s, completed={self.completed})")


def format_profile(p: TaskProfile) -> str:
    """One human line per task: predicted → observed, delay decomposition,
    memory reserved vs high-water (the trace_viewer epilogue)."""
    if p.pred_est_s is not None and p.completed and not p.grow \
            and p.exec_s > 0:
        delta = f"{p.err_ratio * +100:+.1f}%" if p.err_ratio is not None \
            else "n/a"
        run = (f"predicted {p.pred_est_s:.3f}s -> observed "
               f"{p.exec_s:.3f}s ({delta})")
    elif p.completed:
        run = f"ran {p.exec_s:.3f}s"
    elif p.crashed:
        run = "crashed"
    elif p.shed:
        run = "shed"
    else:
        run = "unresolved"
    mem = ""
    if p.reserved_hbm is not None:
        hw = f"{p.hw_bytes / 1e9:.1f}" if p.hw_bytes is not None else "?"
        mem = (f", mem {p.reserved_hbm / 1e9:.1f}GB reserved / "
               f"{hw}GB high-water")
    extra = f", evictions {p.evictions}" if p.evictions else ""
    cal = " [calibrated]" if p.calibrated else ""
    return (f"{p.name or p.uid}: {run}, parked {p.park_s:.3f}s, "
            f"dispatch {p.dispatch_s:.3f}s{mem}{extra}{cal}")


# -- the event-stream join ----------------------------------------------------

def profiles_from_events(events: Any) -> Dict[int, TaskProfile]:
    """Fold a lifecycle window into per-task attribution records. Pure on
    the event list — works on a live tracer snapshot, a flight-recorder
    dump, or a replayed leg equally."""
    out: Dict[int, TaskProfile] = {}

    def get(uid: int) -> TaskProfile:
        p = out.get(uid)
        if p is None:
            p = TaskProfile(uid)
            out[uid] = p
        return p

    for e in events:
        if e.uid < 0:
            continue
        kind = e.kind
        if kind == ev.SUBMIT:
            p = get(e.uid)
            p.name = e.name or p.name
            p.submit_t = e.t
            d = e.data
            if d is not None:
                p.job = d.get("job", "")
                p.pred_est_s = d.get("est_seconds")
                p.pred_hbm = d.get("hbm_bytes")
                core = d.get("core_demand")
                bw = d.get("bw_demand")
                if core is not None:
                    p.demand = max(core, bw if bw is not None else 0.0)
        elif kind in (ev.PARK, ev.REQUEUE, ev.RESTORE):
            p = get(e.uid)
            p.name = e.name or p.name
            if p._park_at is None:
                p._park_at = e.t
        elif kind in (ev.ADMIT, ev.GROW):
            p = get(e.uid)
            p.name = e.name or p.name
            if p._park_at is not None:
                p.park_s += e.t - p._park_at
                p._park_at = None
            p._admit_at = e.t
            p.incarnations += 1
            if e.device >= 0:
                p.devices.append(e.device)
            if kind == ev.GROW:
                p.grow = True
            d = e.data
            if d is not None and "hbm" in d:
                p.reserved_hbm = d["hbm"]
                p.calibrated = True
            elif p.reserved_hbm is None:
                p.reserved_hbm = p.pred_hbm
        elif kind == ev.BEGIN:
            p = get(e.uid)
            if p._admit_at is not None:
                p.dispatch_s += e.t - p._admit_at
                p._admit_at = None
            p._begin_at = e.t
        elif kind in (ev.END, ev.SHRINK):
            p = get(e.uid)
            if p._begin_at is not None:
                p.exec_s += e.t - p._begin_at
                p._begin_at = None
            elif p._admit_at is not None:
                # no BEGIN on this lifecycle (grow deltas, bind residents):
                # the exec span is the residency window
                p.exec_s += e.t - p._admit_at
            p._admit_at = None
            p.end_t = e.t
            p.completed = True
            d = e.data
            if d is not None and "hw" in d:
                p.hw_bytes = d["hw"]
        elif kind == ev.EVICT:
            p = get(e.uid)
            if p._begin_at is not None:
                p.exec_s += e.t - p._begin_at
                p._begin_at = None
            p._admit_at = None
            p.evictions += 1
        elif kind == ev.SHED:
            get(e.uid).shed = True
        elif kind == ev.CRASH:
            p = get(e.uid)
            p.crashed = True
            if p._begin_at is not None:
                p.exec_s += e.t - p._begin_at
                p._begin_at = None
            p._admit_at = None
    return out


# -- per-device occupancy timelines ------------------------------------------

def device_occupancy(events: Any, *,
                     default_demand: float = DEFAULT_DEMAND,
                     timeline_cap: int = 4096) -> Dict[int, Dict[str, Any]]:
    """Reconstruct per-device occupancy-percent timelines from residency
    windows: a task contributes its probed ``demand`` (the dominant
    core/bandwidth share from its SUBMIT payload) from ADMIT/GROW to the
    matching END/SHRINK/EVICT/CRASH. Occupancy is capped at 1.0 — Alg. 3
    legitimately oversubscribes compute slots; the percent answers "how
    busy", not "how oversubscribed".

    Returns ``{device: {"busy_frac", "mean_occupancy", "last", "timeline"}}``
    where ``busy_frac`` is the fraction of the window with ANY resident,
    ``mean_occupancy`` the time-weighted mean demand (both in [0, 1]),
    and ``timeline`` up to ``timeline_cap`` ``(t, occupancy)`` samples."""
    demand_of: Dict[int, float] = {}
    where: Dict[int, Tuple[int, float]] = {}   # uid -> (device, demand)
    load: Dict[int, float] = {}                # device -> raw demand sum
    acc: Dict[int, Dict[str, Any]] = {}
    t0: Optional[float] = None
    t_last: Dict[int, float] = {}
    t_end: Optional[float] = None

    def dev_acc(d: int) -> Dict[str, Any]:
        a = acc.get(d)
        if a is None:
            a = {"busy_s": 0.0, "wsum": 0.0, "timeline": []}
            acc[d] = a
        return a

    def integrate(d: int, t: float) -> None:
        a = dev_acc(d)
        prev = t_last.get(d, t0 if t0 is not None else t)
        span = t - prev
        if span > 0:
            occ = min(load.get(d, 0.0), 1.0)
            a["wsum"] += occ * span
            if occ > 0:
                a["busy_s"] += span
        t_last[d] = t

    def sample(d: int, t: float) -> None:
        tl = dev_acc(d)["timeline"]
        occ = min(load.get(d, 0.0), 1.0)
        if len(tl) < timeline_cap:
            if tl and tl[-1][0] == t:
                tl[-1] = (t, occ)
            else:
                tl.append((t, occ))

    for e in events:
        if t0 is None:
            t0 = e.t
        t_end = e.t
        if e.kind == ev.SUBMIT and e.data is not None and e.uid >= 0:
            core = e.data.get("core_demand")
            bw = e.data.get("bw_demand")
            if core is not None:
                demand_of[e.uid] = max(core, bw if bw is not None else 0.0)
        elif e.kind in (ev.ADMIT, ev.GROW) and e.uid >= 0 and e.device >= 0:
            stale = where.pop(e.uid, None)
            if stale is not None:            # lost close: settle the old dev
                integrate(stale[0], e.t)
                load[stale[0]] = max(load.get(stale[0], 0.0) - stale[1], 0.0)
                sample(stale[0], e.t)
            dm = demand_of.get(e.uid, default_demand)
            integrate(e.device, e.t)
            load[e.device] = load.get(e.device, 0.0) + dm
            where[e.uid] = (e.device, dm)
            sample(e.device, e.t)
        elif e.kind in (ev.END, ev.SHRINK, ev.EVICT, ev.CRASH) \
                and e.uid in where:
            d, dm = where.pop(e.uid)
            integrate(d, e.t)
            load[d] = max(load.get(d, 0.0) - dm, 0.0)
            sample(d, e.t)
    if t_end is not None:
        for d in list(acc):
            integrate(d, t_end)
    out: Dict[int, Dict[str, Any]] = {}
    span = (t_end - t0) if t0 is not None and t_end is not None else 0.0
    for d, a in acc.items():
        out[d] = {
            "busy_frac": a["busy_s"] / span if span > 0 else 0.0,
            "mean_occupancy": a["wsum"] / span if span > 0 else 0.0,
            "last": min(load.get(d, 0.0), 1.0),
            "timeline": a["timeline"],
        }
    return out


# -- Perfetto counter tracks --------------------------------------------------

def chrome_counter_records(events: Any,
                           us: Callable[[float], float]) -> List[dict]:
    """Counter-track records for the Chrome export (``obs.export`` merges
    these when profile counters are enabled): a per-device "occupancy %"
    counter on each device's existing process row, and a fleet-wide
    "prediction error %" track (absolute observed/predicted runtime error
    per completion). ``us`` is the exporter's own timestamp converter, so
    the counters land on the same timeline as the occupancy slices."""
    out: List[dict] = []
    occ = device_occupancy(events)
    for d in sorted(occ):
        for t, frac in occ[d]["timeline"]:
            out.append({"ph": "C", "pid": d, "tid": 0,
                        "name": "occupancy %", "ts": us(t),
                        "args": {"pct": round(frac * 100.0, 1)}})
    profs = profiles_from_events(events)
    err_samples: List[Tuple[float, float]] = []
    for p in profs.values():
        r = p.err_ratio
        if r is not None and p.end_t is not None:
            err_samples.append((p.end_t, abs(r) * 100.0))
    if err_samples:
        out.append({"ph": "M", "pid": _ERR_PID, "tid": 0,
                    "name": "process_name",
                    "args": {"name": "prediction error"}})
        for t, pct in sorted(err_samples):
            out.append({"ph": "C", "pid": _ERR_PID, "tid": 0,
                        "name": "est error %", "ts": us(t),
                        "args": {"pct": round(pct, 1)}})
    return out


# -- the live wrapper ---------------------------------------------------------

class Profiler:
    """Attribution reader over a live ``Tracer`` (and optionally the
    ``CalibrationStore`` sharing its run). Recomputes from the current
    event window on demand — the tracer stays the single source of truth,
    and the profiler adds zero cost to the emission path."""

    def __init__(self, tracer: Any, store: Any = None):
        self.tracer = tracer
        self.store = store

    def profiles(self) -> Dict[int, TaskProfile]:
        return profiles_from_events(self.tracer.events())

    def by_name(self) -> Dict[str, TaskProfile]:
        """Latest profile per task name (parity-friendly: names survive
        re-submission across backends, uids do not)."""
        out: Dict[str, TaskProfile] = {}
        for p in self.profiles().values():
            if p.name:
                out[p.name] = p
        return out

    def device_occupancy(self, **kw) -> Dict[int, Dict[str, Any]]:
        return device_occupancy(self.tracer.events(), **kw)

    def summary(self) -> Dict[str, Any]:
        """Fleet-level attribution rollup (the ``Cluster.profile()``
        no-handle answer): runtime-error stats over completed tasks, the
        queueing decomposition, memory violations, per-device occupancy,
        and — when a calibration store rides along — its accuracy report."""
        profs = list(self.profiles().values())
        done = [p for p in profs if p.completed]
        errs = [abs(p.err_s) for p in done if p.err_s is not None]
        ratios = [abs(p.err_ratio) for p in done if p.err_ratio is not None]
        occ = self.device_occupancy()
        out: Dict[str, Any] = {
            "tasks": len(profs),
            "completed": len(done),
            "crashed": sum(1 for p in profs if p.crashed),
            "shed": sum(1 for p in profs if p.shed),
            "evictions": sum(p.evictions for p in profs),
            "memory_violations": sum(1 for p in profs if p.memory_violation),
            "mean_abs_err_s": sum(errs) / len(errs) if errs else 0.0,
            "mean_abs_err_ratio":
                sum(ratios) / len(ratios) if ratios else 0.0,
            "park_s": sum(p.park_s for p in profs),
            "dispatch_s": sum(p.dispatch_s for p in profs),
            "exec_s": sum(p.exec_s for p in profs),
            "device_occupancy": {
                d: {"busy_frac": o["busy_frac"],
                    "mean_occupancy": o["mean_occupancy"]}
                for d, o in occ.items()},
        }
        if self.store is not None:
            out["calibration"] = self.store.accuracy_report()
        return out
