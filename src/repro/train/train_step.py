"""Train-step factory: loss + grad (+ optional microbatched gradient
accumulation) + sharded AdamW update, ready for ``jax.jit`` with in/out
shardings. This function IS the "GPU task" body for training workloads in the
paper's framework — the scheduler receives its compiler-derived resource vector
(repro.core.probe) before placement.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import init_params, loss_fn
from repro.optim import adamw


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, *,
                    attn_impl: str = "flash",
                    num_microbatches: Optional[int] = None,
                    grad_compressor=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_compressor`` (repro.dist.compression) is applied to gradients before
    the optimizer — with FSDP the compression happens before the cross-pod
    all-reduce that GSPMD inserts at the psum of the data axis.
    """

    def compute_grads(params, batch):
        if not num_microbatches or num_microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, cfg, batch,
                                               attn_impl=attn_impl)
        n = num_microbatches

        def split(x):
            b = x.shape[0]
            assert b % n == 0, (b, n)
            return jnp.moveaxis(x.reshape((n, b // n) + x.shape[1:]), 0, 0)

        micro = jax.tree_util.tree_map(split, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            tot, acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, cfg, mb,
                                               attn_impl=attn_impl)
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (tot + l, acc), None

        (tot, acc), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g),
                                     micro)
        grads = jax.tree_util.tree_map(lambda g: g / n, acc)
        return tot / n, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if grad_compressor is not None:
            grads = grad_compressor(grads)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def abstract_train_state(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                         param_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for (params, opt_state) — no allocation (dry-run)."""
    params_sds = jax.eval_shape(
        functools.partial(init_params, cfg, param_dtype=param_dtype),
        jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(
        functools.partial(adamw.init_state, opt_cfg), params_sds)
    return params_sds, opt_sds
