"""Elastic rescale: re-shard a live train state onto a different mesh.

When a pod loses hosts (or gains them back), training continues on a
shrunken/grown mesh instead of stalling: the sharding rules are re-derived
for the new mesh (divisibility-aware, so a 16->8-way model axis still
shards), and every leaf is re-placed with ``jax.device_put``. The data
pipeline's global batch is re-split over the new data-axis size; the step
function is re-jitted lazily on first call (shape signature unchanged, so
only the partitioning changes).

The scheduler composes with this: a slice task whose device count changed
simply re-enters the queue with an updated ``chips`` in its ResourceVector.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist import sharding as SH


def reshard_state(cfg: ArchConfig, params: Any, opt_state: Any,
                  new_mesh: Mesh) -> Tuple[Any, Any]:
    """Re-place (params, opt_state) onto ``new_mesh`` under re-derived rules."""
    pspecs = SH.param_specs(cfg, jax.eval_shape(lambda t: t, params), new_mesh)
    psh = SH.to_named(pspecs, new_mesh)
    new_params = jax.tree_util.tree_map(jax.device_put, params, psh)
    new_opt = {
        "mu": jax.tree_util.tree_map(jax.device_put, opt_state["mu"], psh),
        "nu": jax.tree_util.tree_map(jax.device_put, opt_state["nu"], psh),
        "step": jax.device_put(opt_state["step"],
                               NamedSharding(new_mesh, P())),
    }
    return new_params, new_opt


def rescale_batch_size(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-device batch constant across the rescale (linear-scaling-rule
    LR adjustments are the optimizer schedule's job)."""
    per_dev = max(global_batch // old_data, 1)
    return per_dev * new_data
