"""Straggler detection and mitigation.

At pod scale one slow host (thermal throttle, failing HBM, noisy neighbour on
DCN) gates every synchronous step. Detection: per-step host timing against a
robust running estimate (median + k*MAD over a window). Mitigation hooks:

  * ``report()`` -> verdict per host (ok / straggler), consumed by the
    launcher to re-shard around the slow host (train.elastic) or by the
    scheduler to mark the device degraded (DeviceState.alive flags);
  * the policy is deliberately decoupled from detection so a deployment can
    choose drop/reshard vs. wait vs. checkpoint-and-migrate.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerVerdict:
    host: int
    median_s: float
    last_s: float
    ratio: float
    is_straggler: bool


class StragglerDetector:
    """Sliding-window median/MAD detector over per-host step times."""

    def __init__(self, n_hosts: int, window: int = 32,
                 threshold: float = 1.5, min_samples: int = 8):
        self.n_hosts = n_hosts
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self._times: List[Deque[float]] = [
            collections.deque(maxlen=window) for _ in range(n_hosts)]

    def record_step(self, host: int, seconds: float) -> None:
        self._times[host].append(seconds)

    @staticmethod
    def _median(xs: List[float]) -> float:
        ys = sorted(xs)
        n = len(ys)
        return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])

    def report(self) -> Dict[int, StragglerVerdict]:
        # global median over all hosts' recent steps = the fleet's pace
        all_times = [t for dq in self._times for t in dq]
        if len(all_times) < self.min_samples:
            return {}
        fleet = self._median(all_times)
        out = {}
        for h, dq in enumerate(self._times):
            if not dq:
                continue
            mine = self._median(list(dq))
            ratio = mine / max(fleet, 1e-12)
            out[h] = StragglerVerdict(
                host=h, median_s=fleet, last_s=mine, ratio=ratio,
                is_straggler=ratio > self.threshold and len(dq) >= 4)
        return out

    def stragglers(self) -> List[int]:
        return [h for h, v in self.report().items() if v.is_straggler]
