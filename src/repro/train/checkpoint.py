"""Checkpoint/restore for train state — the fault-tolerance substrate.

Layout: one directory per step, ``step_<N>/``, containing a manifest
(pytree structure + shapes/dtypes + sharding specs as text) and one .npy per
leaf. Writes go to a temp dir and are atomically renamed, so a crash
mid-save never corrupts the newest checkpoint (restore picks the latest
COMMITTED step). ``AsyncCheckpointer`` overlaps serialization with compute:
save() snapshots device arrays to host (blocking only on the device->host
copy) and the write happens on a worker thread — the train loop continues
into the next step immediately.

On a multi-host pod each host writes only the shards it owns
(``addressable_shards``); restore re-assembles per-host. On this 1-device
container that degenerates to full arrays, same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_COMMIT = "COMMITTED"


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_path(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """np.save cannot round-trip ml_dtypes (bfloat16 etc.) — store the raw
    bits as a same-width uint view; the manifest records the logical dtype."""
    if arr.dtype.kind not in "biufc":
        return arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32,
                         8: np.uint64}[arr.dtype.itemsize])
    return arr


def _from_storable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    import ml_dtypes  # jax dependency, always present
    want = np.dtype(getattr(ml_dtypes, dtype_str, dtype_str))
    if arr.dtype != want:
        return arr.view(want)
    return arr


def save(ckpt_dir: str, step: int, state: Any) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    leaves, treedef = _flatten(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, _leaf_path(i)),
                _to_storable(np.asarray(leaf)))
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, name, _COMMIT)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[int, Any]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — leaves are placed directly onto their devices."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        (manifest["n_leaves"], len(leaves_like))
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(d, _leaf_path(i)))
        arr = _from_storable(arr, manifest["dtypes"][i])
        expect = tuple(getattr(ref, "shape", arr.shape))
        assert arr.shape == expect, f"leaf {i}: {arr.shape} != {expect}"
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out)


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint writes with training compute.

    save(): device->host snapshot happens inline (cheap, bounded by PCIe/DMA),
    serialization + fsync happen on the worker thread. At most one write is in
    flight; a second save() waits for the first (backpressure rather than
    unbounded host memory growth).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot to host NOW so the caller may donate/mutate device arrays
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)

        def _write():
            save(self.ckpt_dir, step, host_state)
            prune(self.ckpt_dir, keep=self.keep)
            self.last_committed = step

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
