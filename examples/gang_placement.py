"""Gang placement walkthrough: multi-chip tasks on a topology-aware cluster.

    1. build an 8-chip (1 pod, 2x4) topology and a GangScheduler over it;
    2. submit a mixed open-arrival stream — single-chip decode-style jobs
       plus chips=4 sharded-train gangs — through the same Cluster front
       door, on the virtual-clock backend;
    3. watch a gang get a CONTIGUOUS 4-chip group atomically (never 4
       independent single-chip placements) and its collectives charged on
       the group's ICI links;
    4. re-run the same trace on the LIVE executor: the gang's unit group is
       dispatched as one bound device set (the runner receives the whole
       device list);
    5. deadline shedding: with shed_late=True a request that is still parked
       when its deadline passes is SHED at the next drain, not served late.

    PYTHONPATH=src python examples/gang_placement.py
"""
import time

import numpy as np

from repro.core.cluster import Cluster, JobStatus
from repro.core.scheduler import GangScheduler
from repro.core.workloads import gang_mix, make_gang_job


def main():
    # --- sim backend: placement study on the virtual clock -----------------
    jobs = gang_mix(seed=0, n_singles=4, n_gangs=3, chip_choices=(2, 4),
                    probe_singles=False)
    sched = GangScheduler(pods=1, rows=2, cols=4)   # 8 chips
    with Cluster(sched, workers=16, backend="sim") as cluster:
        handles = [cluster.submit(j) for j in jobs]
        cluster.drain()
    assert all(h.status is JobStatus.DONE for h in handles)
    print("sim backend: all", len(handles), "jobs done at virtual t="
          f"{cluster.now:.1f}s")
    for h in handles:
        rec = h.records[-1]
        tag = (f"{rec.gang_chips}-chip group @dev{rec.device}"
               if rec.gang_chips > 1 else f"dev{rec.device}")
        print(f"  {h.job.name:>12s}: {tag}  "
              f"queue={rec.t_start - rec.t_queue:5.1f}s")

    # --- live backend: the gang's unit group is ONE bound dispatch ---------
    bound_groups = []

    def gang_runner(devices):
        # a chips>1 task receives the ORDERED device list of its reservation
        bound_groups.append(devices if isinstance(devices, list)
                            else [devices])
        time.sleep(0.002)

    rng = np.random.default_rng(1)
    live_sched = GangScheduler(pods=1, rows=2, cols=4)
    with Cluster(live_sched, workers=8) as cluster:
        gang = make_gang_job(rng, chips=4, name="train-x4")
        h = cluster.submit(gang, runners=[gang_runner])
        h.result(timeout=30)
    assert h.status is JobStatus.DONE and len(bound_groups[0]) == 4
    print(f"\nlive backend: gang {h.job.name!r} ran as one bound group of "
          f"{len(bound_groups[0])} devices "
          f"(gang_chips={h.records[0].gang_chips})")

    # --- deadline shedding --------------------------------------------------
    shed_sched = GangScheduler(pods=1, rows=1, cols=1)
    with Cluster(shed_sched, workers=4, backend="sim",
                 shed_late=True) as cluster:
        rng = np.random.default_rng(2)
        hog = cluster.submit(make_gang_job(rng, chips=1, name="hog",
                                           per_chip_gb=(10, 12),
                                           seconds=(30, 30)))
        late = cluster.submit(make_gang_job(rng, chips=1, name="late",
                                            per_chip_gb=(10, 12)),
                              deadline_s=5.0)   # parked behind hog
        cluster.drain()
    assert hog.status is JobStatus.DONE
    assert late.status is JobStatus.SHED
    print(f"\nshedding: {late.job.name!r} parked past its 5s deadline -> "
          f"{late.status.value} (never admitted late); stats: "
          f"{ {k: v for k, v in cluster.stats().items() if k in ('completed', 'shed')} }")
    print("\ngang_placement OK")


if __name__ == "__main__":
    main()
