"""Scenario: watch the scheduler think — record a small mixed trace and
write a Perfetto-loadable Chrome trace-event JSON.

A 2-device preemptive cluster on the virtual clock serves a burst of
mixed-priority jobs; mid-run one device dies (its resident is evicted,
requeued, and resumes on the survivor — a cross-device migration arc) and
later revives. The whole lifecycle lands in ``cluster.trace``:

  * per-device occupancy tracks (one slice per residency),
  * a waiter-queue-depth counter track,
  * instant markers for the death/revive,
  * a flow arrow stitching the evicted task's device-0 → device-1 arc.

The cluster runs CALIBRATED (``calibrate=True``), so the export also
carries the profiling counter tracks — per-device observed occupancy %
and the fleet prediction-error % — and the epilogue prints each job's
predicted-vs-observed attribution line (``handle.profile()``: runtime
error, parked/dispatch decomposition, memory reserved vs high-water)
alongside its decision verdicts (``handle.explain()``).

Open the written JSON in chrome://tracing or https://ui.perfetto.dev.

    PYTHONPATH=src python examples/trace_viewer.py
"""
from repro.core.cluster import Cluster
from repro.core.scheduler import PreemptiveAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.obs.explain import format_verdicts
from repro.obs.profile import format_profile
from repro.obs.export import trace_summary
from repro.obs.metrics import metrics_from_events
from repro.obs.replay import validate_lifecycles

GB = 1024**3
OUT = "trace_viewer.json"


def mk_job(name, mem_gb, est, chips=1):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=0.5, bw_demand=0.5, chips=chips)
    task = Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)
    return Job(tasks=[task], name=name)


def main():
    cluster = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                      trace=True, calibrate=True)
    handles = []
    # device 0 dies at t=0.5 (virtual): its resident is evicted, requeued,
    # and resumes on device 1 — the cross-device flow in the viewer
    cluster._sim._failure_pending = (0.5, 0)

    for i in range(4):
        handles.append(cluster.submit(mk_job(f"batch/{i}", mem_gb=12.0,
                                             est=1.0), priority=0))
    cluster.run_until(0.8)
    # urgent late arrivals overtake the parked backlog (EDF within class)
    handles.append(cluster.submit(mk_job("urgent/a", mem_gb=9.0, est=0.3),
                                  priority=5, deadline_s=1.0))
    handles.append(cluster.submit(mk_job("urgent/b", mem_gb=9.0, est=0.3),
                                  priority=5, deadline_s=2.0))
    # keep device 0 down long enough that the evicted resident resumes on
    # device 1 (the migration arc), then bring it back for the backlog
    cluster.run_until(3.0)
    cluster.sched.revive(0)
    cluster.drain()

    problems = validate_lifecycles(cluster.trace.events(),
                                   require_terminal=True)
    assert not problems, problems

    doc = cluster.export_trace(OUT)
    s = trace_summary(doc)
    print(f"wrote {OUT}: {s['slices']} slices on devices {s['devices']}, "
          f"{s['flows']} flow(s) ({s['cross_device_flows']} cross-device), "
          f"{s['counter_samples']} counter samples (queue depth + "
          f"occupancy % + est error %)")

    reg = metrics_from_events(cluster.trace.events())
    snap = reg.snapshot()
    qd = snap["histograms"]["queueing_delay_s"]
    print(f"queueing delay: n={qd['n']} p50={qd['p50']:.3f}s "
          f"p99={qd['p99']:.3f}s; "
          f"migrations={snap['counters'].get('migrations', 0)}")

    # what each job actually did vs what its probe predicted — runtime
    # error, the parked/dispatch decomposition, reserved vs high-water
    print("\npredicted vs observed:")
    for h in handles:
        for p in h.profile().values():
            print(f"  {format_profile(p)}")

    # why did each job wait / move / land where it did — the verdict
    # window every decision site recorded alongside the event stream
    print("\ndecision verdicts:")
    for h in handles:
        for name, verdicts in h.explain().items():
            print(f"  {name}:")
            for line in format_verdicts(verdicts).splitlines():
                print(f"    {line}")
    print("open the JSON in chrome://tracing or https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
