"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing + resume.

This is the deliverable-(b) end-to-end example: real data pipeline ->
sharded train step -> AdamW -> async checkpoints, the loss demonstrably
decreasing. On a pod the same code runs the full configs (see
repro.launch.train --full).

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--resume]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig
from repro.configs import registry
from repro.launch import train as T

# ~100M params: 2*V*d + L*(4*d^2 + 3*d*f) = 2*32000*640 + 12*(4*640^2 +
# 3*640*2560) ≈ 41M + 12*6.6M ≈ 120M
CONFIG_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=2560,
    vocab=32000,
    mlp_act="silu_gated",
    remat_policy="nothing",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    print(f"lm-100m: {CONFIG_100M.param_count() / 1e6:.0f}M params")
    registry.ARCHS[CONFIG_100M.name] = CONFIG_100M  # selectable config
    res = T.train(CONFIG_100M.name, steps=args.steps, batch=args.batch,
                  seq=args.seq, reduced=False, ckpt_dir=args.ckpt_dir,
                  ckpt_every=50, resume=args.resume, attn_impl="flash",
                  log_every=20, lr=1e-3)
    first, last = res["losses"][0], res["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {res['steps']} steps "
          f"({res['steps'] / res['wall_s']:.2f} steps/s on CPU)")
    assert last < first, "loss must decrease"
    print("train_100m OK")


if __name__ == "__main__":
    main()
