"""Scenario: deadline enforcement by EVICTION on a shared cluster.

A background training fleet saturates the device memory of a 4-chip node
while urgent inference requests with tight deadlines keep arriving. The
same open-arrival trace is replayed twice on the virtual clock:

  * **admission-only** (the paper's scheduler): an urgent request parks
    behind a ~20-second training job and blows its deadline;
  * **preemptive** (`PreemptiveAlg3Scheduler` + ``Cluster(preempt=True)``):
    the request evicts the min-cost background resident — the victim's
    remaining work is banked, it re-enters the queue at the front of its
    class, and it resumes (on whatever device frees first — migration is
    just requeue + placement) for remaining + checkpoint penalty.

Then a short LIVE demonstration runs the cooperative-checkpoint path: a
real executor preempts a running job mid-flight, its ``on_preempt`` hook
fires (where a training task would call ``repro.train.checkpoint.save``),
and the resumed dispatch completes the job.

    PYTHONPATH=src python examples/preemptive_cluster.py
"""
import time

from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.preemption import PreemptionPolicy
from repro.core.scheduler import MGBAlg3Scheduler, PreemptiveAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.workloads import overload_mix

DEVICES = 4
GB = 1024**3


def replay(sched, rows, preempt=None):
    c = Cluster(sched, workers=64, backend="sim", preempt=preempt)
    entries = []
    for row in rows:
        c.run_until(row["t"])
        entries.append((row, c.submit(row["job"], priority=row["priority"],
                                      deadline_s=row["deadline_s"])))
    c.drain()
    urgent = [(r, h) for r, h in entries if r["kind"] == "urgent"]
    met = sum(1 for r, h in urgent if h.status is JobStatus.DONE
              and h.job.finish_t <= r["t"] + r["deadline_s"])
    return met, len(urgent), c.stats()


def sim_comparison():
    rows1 = overload_mix(0, n_background=6, n_bystander=2, n_urgent=10)
    met, total, _ = replay(MGBAlg3Scheduler(DEVICES), rows1)
    rows2 = overload_mix(0, n_background=6, n_bystander=2, n_urgent=10)
    sched = PreemptiveAlg3Scheduler(
        DEVICES, preempt_policy=PreemptionPolicy(budget=6))
    met_p, total_p, stats = replay(sched, rows2, preempt=True)
    print(f"[sim] admission-only : {met}/{total} urgent deadlines met")
    print(f"[sim] preemptive EDF : {met_p}/{total_p} urgent deadlines met "
          f"({stats['preemptions']} preemption(s), "
          f"{stats['migrations']} migration(s))")
    assert met_p > met


def live_cooperative_checkpoint():
    def mk_job(name, gb, est, prio=0):
        vec = ResourceVector(hbm_bytes=int(gb * GB), flops=1e9,
                             bytes_accessed=1e9, est_seconds=est,
                             core_demand=0.4, bw_demand=0.3)
        unit = UnitTask(fn=None, memobjs=frozenset({name}), resources=vec,
                        name=name)
        return Job(tasks=[Task(units=[unit], name=name)], name=name,
                   priority=prio)

    sched = PreemptiveAlg3Scheduler(
        1, preempt_policy=PreemptionPolicy(min_runtime_s=0.0))
    c = Cluster(sched, workers=4)
    events = []

    bg = ExecJob(job=mk_job("train-bg", 10, 5.0), runners=[None],
                 on_preempt=lambda t: events.append(f"checkpoint({t.name})"))

    attempts = []

    def cooperative_runner(device):
        # a cooperative task polls its job's `preempted` event between steps
        # and returns early once evicted; the resumed dispatch (attempt 2)
        # has only the checkpointed remainder left and finishes at once
        attempts.append(device)
        if len(attempts) == 1 and bg.preempted.wait(5.0):
            events.append("stopped-early")
        else:
            events.append("finished")
    bg.runners[0] = cooperative_runner

    h_bg = c.submit(bg)
    time.sleep(0.2)
    h_urgent = c.submit(mk_job("urgent", 10, 0.05, prio=5),
                        runners=[lambda d: time.sleep(0.02)])
    h_urgent.result(timeout=30)
    c.drain()
    c.shutdown()
    print(f"[live] events: {events}; statuses: "
          f"{[(h.job.name, h.status.value) for h in c.handles]}; "
          f"{sched.preemptions} preemption(s)")
    assert h_bg.status is JobStatus.DONE
    assert any(e.startswith("checkpoint") for e in events)


if __name__ == "__main__":
    sim_comparison()
    live_cooperative_checkpoint()
    print("preemptive cluster demo OK")
