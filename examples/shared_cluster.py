"""Scenario: a shared multi-accelerator node running a mixed batch of REAL
model workloads (train steps, prefill, decode) from independent "users" under
the paper's scheduler — the full compiler-guided pipeline with live JAX
execution through the event-driven executor (blocked jobs hold no thread;
completions wake the waiter queue), plus a mid-run device failure to exercise
the fault-tolerance path and a decode fleet far larger than the execution
pool.

    PYTHONPATH=src python examples/shared_cluster.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob, Executor
from repro.core.probe import probe_fn
from repro.core.scheduler import MGBAlg3Scheduler, SAScheduler
from repro.core.task import Job, Task, UnitTask
from repro.models.model import init_params
from repro.optim import adamw
from repro.serve.decode import make_prefill_step
from repro.train.train_step import make_train_step

BATCH, SEQ = 4, 128


def make_train_job(arch: str, idx: int, steps: int = 3) -> ExecJob:
    cfg = get_arch(arch).reduced()
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(cfg, opt_cfg, attn_impl="flash_jnp")
    params = init_params(cfg, jax.random.PRNGKey(idx))
    opt_state = adamw.init_state(opt_cfg, params)
    rng = np.random.default_rng(idx)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, SEQ), np.int32))
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.embedding_frontend_stub:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, SEQ, cfg.d_model), np.float32))
    vec = probe_fn(step, params, opt_state, batch, work_scale=steps)
    name = f"train-{arch}-{idx}"

    state = {"params": params, "opt": opt_state}

    def runner(device):
        jstep = jax.jit(step)
        for _ in range(steps):
            state["params"], state["opt"], m = jstep(
                state["params"], state["opt"], batch)
        jax.block_until_ready(m["loss"])

    unit = UnitTask(fn=None, memobjs=frozenset({name}), resources=vec,
                    name=name)
    return ExecJob(job=Job(tasks=[Task(units=[unit], name=name)], name=name),
                   runners=[runner])


def make_serve_job(arch: str, idx: int) -> ExecJob:
    cfg = get_arch(arch).reduced()
    prefill = make_prefill_step(cfg, attn_impl="flash_jnp")
    params = init_params(cfg, jax.random.PRNGKey(100 + idx))
    rng = np.random.default_rng(100 + idx)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, SEQ), np.int32))
    batch = {"tokens": tok}
    if cfg.embedding_frontend_stub:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, SEQ, cfg.d_model), np.float32))
    vec = probe_fn(prefill, params, batch)
    name = f"serve-{arch}-{idx}"

    def runner(device):
        logits, cache = jax.jit(prefill)(params, batch)
        jax.block_until_ready(logits)

    unit = UnitTask(fn=None, memobjs=frozenset({name}), resources=vec,
                    name=name)
    return ExecJob(job=Job(tasks=[Task(units=[unit], name=name)], name=name),
                   runners=[runner])


def build_jobs():
    jobs = []
    for i, arch in enumerate(["gemma2-9b", "qwen1.5-32b"]):
        jobs.append(make_train_job(arch, i))
    for i, arch in enumerate(["mixtral-8x7b", "falcon-mamba-7b",
                              "zamba2-2.7b", "musicgen-large"]):
        jobs.append(make_serve_job(arch, i))
    return jobs


def main():
    print("building 6 jobs (2 train + 4 serve) from 6 architectures...")
    jobs = build_jobs()
    for j in jobs:
        r = j.job.tasks[0].resources
        print(f"  {j.job.name:24s} mem={r.hbm_bytes / 1e6:7.1f} MB "
              f"demand={r.demand:.2f} est={r.est_seconds * 1e3:.2f} ms(tpu)")

    print("\n-- MGB Alg.3 on 2 virtual devices --")
    sched = MGBAlg3Scheduler(num_devices=2)
    t0 = time.time()
    stats = Executor(sched, workers=4).run(jobs)
    print(f"completed={stats['completed']} crashed={stats['crashed']} "
          f"makespan={stats['makespan_s']:.2f}s")
    by_dev = {}
    for uid, dev in sched.placements:
        by_dev.setdefault(dev, 0)
        by_dev[dev] += 1
    print("tasks per device:", by_dev)

    print("\n-- same jobs, SA baseline (one job per device) --")
    jobs2 = build_jobs()
    stats_sa = Executor(SAScheduler(num_devices=2), workers=2).run(jobs2)
    print(f"completed={stats_sa['completed']} "
          f"makespan={stats_sa['makespan_s']:.2f}s "
          f"(MGB speedup {stats_sa['makespan_s'] / stats['makespan_s']:.2f}x "
          f"on live CPU execution)")

    print("\n-- fault tolerance: kill device 0 mid-run --")
    sched3 = MGBAlg3Scheduler(num_devices=2)
    jobs3 = build_jobs()
    ex3 = Executor(sched3, workers=4)
    import threading

    def killer():
        time.sleep(0.3)
        evicted = sched3.mark_dead(0)
        print(f"  [failure injected] device 0 dead, {len(evicted)} task(s) "
              "evicted; survivors reschedule on device 1")
    threading.Thread(target=killer).start()
    stats3 = ex3.run(jobs3)
    print(f"completed={stats3['completed']} crashed={stats3['crashed']} "
          f"(all work landed on the surviving device)")
    assert stats3["completed"] + stats3["crashed"] == len(jobs3)

    print("\n-- decode fleet: 64 streamed decode requests, pool of 2, "
          "open arrival --")
    # the serving-scale path: every request is a task submitted to the live
    # Cluster AS IT ARRIVES — no pre-declared batch. Blocked requests park
    # in the scheduler's admission queue (no thread each) and completions
    # wake the next admission. Decode traffic is submitted at priority 5 so
    # it outranks the background training job streamed alongside it, and
    # each request carries a deadline (EDF within the priority class). One
    # jitted prefill is shared by the whole fleet.
    cfg = get_arch("zamba2-2.7b").reduced()
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="flash_jnp"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32), np.int32))
    fleet_batch = {"tokens": tok}
    if cfg.embedding_frontend_stub:
        fleet_batch["embeds"] = jnp.asarray(
            rng.standard_normal((2, 32, cfg.d_model), np.float32))
    vec = probe_fn(prefill, params, fleet_batch)

    def decode_runner(device):
        logits, _ = prefill(params, fleet_batch)
        jax.block_until_ready(logits)

    t0 = time.time()
    with Cluster(MGBAlg3Scheduler(num_devices=2), workers=2) as cluster:
        background = cluster.submit(make_train_job("gemma2-9b", 7),
                                    priority=0)
        handles = []
        for i in range(64):
            name = f"decode-{i}"
            unit = UnitTask(fn=None, memobjs=frozenset({name}),
                            resources=vec, name=name)
            handles.append(cluster.submit(
                ExecJob(job=Job(tasks=[Task(units=[unit], name=name)],
                                name=name),
                        runners=[decode_runner]),
                priority=5, deadline_s=30.0))
        first = handles[0].result(timeout=60)   # a single request's future
        cluster.drain()
        stats4 = cluster.stats()
    done = sum(1 for h in handles if h.status is JobStatus.DONE)
    print(f"completed={done}/64 decode + background train "
          f"{background.status.value} in {time.time() - t0:.2f}s "
          f"with 2 pool threads ({stats4['sched_attempts']} admission "
          f"attempts; first request {len(first)} record(s))")
    assert done == 64 and stats4["completed"] == 65
    print("\nshared_cluster OK")


if __name__ == "__main__":
    main()
