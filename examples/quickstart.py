"""Quickstart: the paper's full pipeline on a toy pair of jobs.

    1. write two independent "applications" as JAX computations with lazy
       buffers (device-independent, like the paper's lazy runtime);
    2. build GPU tasks (Alg. 1 merges kernels sharing buffers);
    3. probe each task's resource vector from the XLA compiled artifact;
    4. let the MGB scheduler place them on a 2-device system;
    5. execute for real — twice: once through the one-shot ``Executor.run``
       shim (closed batch), once through the streaming ``Cluster.submit``
       path (open arrival, the serving front door).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lazy
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob, Executor
from repro.core.probe import probe_fn
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Job, Task, UnitTask
from repro.core.taskgraph import build_gpu_tasks


def main():
    # --- an "application": y = relu(x @ w) summed, then a second kernel that
    # reuses y (so Alg. 1 must merge them into one GPU task) ---------------
    n = 512

    def kernel_a(x, w):
        return jax.nn.relu(x @ w)

    def kernel_b(y):
        return jnp.tanh(y).sum()

    # lazy buffers record alloc/h2d; nothing touches a device yet
    rng = np.random.default_rng(0)
    bufs = {
        "x": lazy.LazyBuffer("x").h2d(rng.standard_normal((n, n),
                                                          dtype=np.float32)),
        "w": lazy.LazyBuffer("w").h2d(rng.standard_normal((n, n),
                                                          dtype=np.float32)),
    }

    # probes: resource vectors from the compiled artifacts
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec_a = probe_fn(kernel_a, sds, sds)
    vec_b = probe_fn(kernel_b, sds)
    print(f"probe A: {vec_a.hbm_bytes / 1e6:.1f} MB, "
          f"{vec_a.flops:.2e} flops, demand {vec_a.demand:.2f}")
    print(f"probe B: {vec_b.hbm_bytes / 1e6:.1f} MB, "
          f"{vec_b.flops:.2e} flops, demand {vec_b.demand:.2f}")

    # Alg. 1: kernel_a writes y, kernel_b reads y -> one merged task
    units = [
        UnitTask(fn=kernel_a, memobjs=frozenset({"x", "w", "y"}),
                 resources=vec_a, name="matmul-relu"),
        UnitTask(fn=kernel_b, memobjs=frozenset({"y"}),
                 resources=vec_b, name="tanh-sum"),
    ]
    tasks = build_gpu_tasks(units)
    print(f"Alg.1 merged {len(units)} kernels into {len(tasks)} GPU task(s): "
          f"{tasks[0]}")

    # two identical applications race for 2 devices under MGB Alg. 3
    sched = MGBAlg3Scheduler(num_devices=2)
    results = {}

    def make_app(app_id):
        mybufs = {k: lazy.LazyBuffer(f"{app_id}/{k}").h2d(b.ops[-1].payload)
                  for k, b in bufs.items()}

        def runner(device):
            arrs = lazy.kernel_launch_prepare(mybufs, device)
            y = jax.jit(kernel_a)(arrs["x"], arrs["w"])
            results[app_id] = float(jax.jit(kernel_b)(y))

        unit = UnitTask(fn=None, memobjs=frozenset(mybufs), resources=vec_a,
                        name=f"{app_id}-task")
        job = Job(tasks=[Task(units=[unit], name=f"{app_id}-task")],
                  name=app_id)
        return ExecJob(job=job, runners=[runner], buffers=mybufs)

    # one-shot compatibility shim: declare the whole batch, run, report
    ex = Executor(sched, workers=2)
    stats = ex.run([make_app("app1"), make_app("app2")])
    print(f"executor: {stats['completed']} jobs done, "
          f"{stats['crashed']} crashed, makespan {stats['makespan_s']:.3f}s")
    print("placements (task uid -> device):", sched.placements)
    print("results:", {k: round(v, 3) for k, v in results.items()})
    assert stats["completed"] == 2 and stats["crashed"] == 0

    # streaming path: the same apps arrive one by one at a live Cluster —
    # submit returns a JobHandle immediately, work may already be in flight,
    # and priority/deadline stamps rank the admission queue
    with Cluster(MGBAlg3Scheduler(num_devices=2), workers=2) as cluster:
        h1 = cluster.submit(make_app("app3"), priority=1)
        h2 = cluster.submit(make_app("app4"), deadline_s=5.0)  # EDF hint
        print(f"streaming: submitted while h1 is {h1.status.value}; "
              f"app4 records: {[r.task for r in h2.result(timeout=30)]}")
        cluster.drain()
        assert h1.status is JobStatus.DONE and h2.status is JobStatus.DONE
    print("results:", {k: round(v, 3) for k, v in results.items()})
    print("quickstart OK")


if __name__ == "__main__":
    main()
